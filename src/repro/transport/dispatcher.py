"""Multi-peer ifunc dispatcher: N peers x M rings, credit-based flow
control, per-peer backpressure, and a fairness-aware poll loop.

This replaces the single-slot ``poll_ring`` pattern: instead of one source
spinning on one ring, a :class:`Dispatcher` owns any number of
:class:`Peer` s — each a (fabric, channel(s), mailbox(s), target context)
bundle on *any* backend (RDMA host, device mesh, loopback/CSD) — and

* ``send`` consumes a credit (one free ring slot) or reports backpressure
  instead of silently overwriting unconsumed frames;
* credits return as the target's sweep advances its mailbox ``consumed``
  counter (the credit-return counter a real target writes back);
* ``poll`` drains mailboxes deficit-round-robin, starting one past the
  ring served first last time, so a chatty peer cannot starve the rest;
* all sends go through a shared :class:`ProgressEngine`, so batching,
  in-flight windows, and completions are uniform across fabrics.

The dispatcher also owns the *cached-invocation fast path* (paper §3.4):

* every frame is packed straight into the engine's per-peer slab cell for
  its ring slot (``pack_frame_into``/``seal_frame``) — the send path
  allocates no per-message buffers;
* a peer's first delivery of an ifunc ships a FULL frame; once the
  delivery is confirmed (the target's link cache provably holds the code
  digest) subsequent sends of the same handle flip to SLIM frames — header
  + payload, code elided;
* a SLIM frame that misses the target's cache (eviction, restart) comes
  back as ``NACK_UNCACHED``: the dispatcher rebuilds the FULL frame from
  the handle's library + the slab-resident payload and retransmits it
  transparently, ahead of any newer traffic to that peer;
* device-mesh lanes are always SLIM-eligible — the μVM program is bound at
  mailbox-open time, so code words never need depositing over the ICI.

And the *result-return path* (the task runtime's wire, see ``repro.tasks``):

* a request carrying a nonzero ``corr_id`` asks for the ifunc's output
  back; host peers get a *reply ring* — a source-owned mailbox the target
  writes ``FLAG_REPLY`` frames into — attached via ``attach_reply_ring``;
* the poll loop, when it executes a corr-carrying request at a host peer,
  captures the ifunc's ``target_args["result"]`` (or the exception it
  raised — the slot is consumed, not wedged) and posts the encoded value
  as a reply frame with the same corr_id; ``poll_replies`` drains reply
  rings and hands ``(corr_id, value)`` to the registered ``reply_router``;
* device-mesh lanes have no reverse ring: the sweep's READY results *are*
  the replies — the dispatcher correlates them to corr-ids by the
  (shard, slot) coordinates each send staged into and routes them through
  the same ``reply_router``;
* encoding is delegated to a pluggable ``reply_codec`` (the task layer's
  wire module) so the transport stays value-format-agnostic.

Plus the flow layer's *continuation frames* and the *liveness floor*:

* ``send``/``send_ifunc`` carry an optional packed continuation
  descriptor (frame v2.2 ``cont`` section, host fabrics only); the
  on-the-fly SLIM repack and the NACK FULL-rebuild both preserve it, so
  a retransmitted hop never loses its route;
* every tracked in-flight frame is timestamped: ``per_peer_stats()``
  surfaces the oldest age per peer, and ``drain(deadline=...)`` fails
  the futures of frames stuck at a wedged peer (``fail_inflight``)
  instead of letting them hang forever.

And *coalesced dispatch* (frame v2.3 ``FLAG_AGG``), the small-message
rate lever:

* with :meth:`set_coalescing` enabled, a cache-warm ``send_ifunc`` to a
  host peer does not claim a ring slot — it lands in that peer's
  per-(peer, ring) coalescing queue.  The queue flushes into ONE
  aggregate container (one put, one slot, one credit, one trailer spin
  for K invocations) on any of: the slot byte budget filling, the
  sub-record cap, an explicit ``flush``/``drain``, or the age bound
  ``agg_max_age`` checked each poll.  A queue holding a single record
  flushes as a plain SLIM singleton — the latency path never regresses;
* the target decodes the whole container in one ``poll_ifunc`` pass and
  reports per-sub-record statuses (``Mailbox.last_agg``): a sub-record
  whose digest was evicted NACKs *individually* and is rebuilt as a FULL
  singleton retransmit — its executed siblings are never replayed — on
  the same quiescence-gated resend queue per-peer FIFO already rides;
* replies coalesce symmetrically: the corr-carrying records of one
  aggregate post their results as ONE ``FLAG_AGG|FLAG_REPLY`` frame into
  the reply ring, and ``poll_replies`` demuxes it back per corr_id;
* unbudgeted polls (``drain``) sweep a whole ring's worth of ready slots
  per lane visit instead of one message per poll-loop round — budgeted
  polls keep the historical one-per-lane-per-round fairness contract;
* device-mesh lanes never coalesce: the deposit/sweep pipeline already
  batches generation-wide (aggregates are host-tier by construction).

And *streamed large payloads* (frame v2.5 ``FLAG_STREAM``), the
64KiB-cliff killer on the other end of the size spectrum:

* with :meth:`set_streaming` enabled, a payload larger than the stream
  threshold no longer store-and-forwards through one slot-bounded frame —
  :meth:`send_stream` opens a FLAG_STREAM frame (header + descriptor +
  ``window x cell`` chunk cells) in ONE ring slot and the dispatcher's
  chunk pump (:meth:`poll` / :meth:`drain` / :meth:`flush`) posts the
  payload as pipelined per-chunk puts, each sealed by its own delivery
  barrier, at most ``window`` chunks ahead of the target's consume
  cursor (``Mailbox.stream_consumed``);
* ``send_ifunc`` / coalesced enqueues route oversized payloads into the
  stream path automatically (host, non-striped peers — a stream would
  wedge a striped rotation);
* per-peer wire codecs (``add_peer(codec=...)``) transform chunk bytes
  in flight — a chunk that doesn't shrink ships raw, so negotiation
  never inflates the wire;
* SLIM streams NACK at the descriptor exactly like singletons: the
  rebuild re-opens the stream FULL from chunk 0 under a fresh nonce (no
  chunks executed — the miss surfaces before any chunk is consumed), on
  the same quiescence-gated resend queue; ``fail_inflight`` / ``drain
  (deadline=)`` resolve a half-arrived stream's future like any tracked
  frame and kill its pump.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace

from repro.core import frame as F
from repro.obs import Obs
from repro.transport import codec as WC
from repro.transport.fabric import Fabric, TransportError
from repro.transport.progress import ProgressEngine

DEFAULT_SLOT_SIZE = 64 << 10
DEFAULT_N_SLOTS = 8

#: the full per-peer stats schema, seeded at construction (and by
#: ``Peer.reset_stats``) so ``per_peer_stats()`` always returns the same
#: keys — increment sites do plain ``+= 1``, never ``.get(k, 0)``
_PEER_STAT_KEYS = (
    "sent", "bytes", "delivered", "rejected", "backpressure",
    "inflight_polls", "slim_sent", "nacks", "resent", "replies", "errors",
    "coalesced", "agg_sent", "agg_subs", "agg_replies", "agg_harvest_lost",
    "nack_lost", "reply_rejects", "streams", "stream_chunks", "timed_out",
    "fenced_orphans", "dropped_puts")

_API = None      # repro.core.api, imported lazily (it imports codegen —
#                  the transport layer must stay importable without it)
#                  and memoized: poll/sweep must not pay the import
#                  machinery per call


def _api():
    global _API
    if _API is None:
        from repro.core import api
        _API = api
    return _API


@dataclass
class _TxRec:
    """Source-side record of one in-flight frame (for digest confirmation,
    NACK retransmission, reply correlation, and liveness tracking).
    ``subs`` non-None marks an aggregate container: the listed
    :class:`_PendingSub` records are what the frame actually carries."""

    name: str
    digest: bytes
    handle: object          # IfuncHandle (None for raw-frame sends)
    slim: bool
    corr_id: int = 0
    sent_at: float = field(default_factory=time.monotonic)
    subs: list | None = None
    stream: object = None   # _StreamTx when this slot holds a FLAG_STREAM
    #                         frame (the pump's source-side state)
    span: object = None     # open obs wire span (tracing runs only): put ->
    #                         delivery confirmation / NACK / reject


@dataclass(slots=True)
class _StreamTx:
    """Source-side state of one streamed payload: the stable payload view
    (zero-copy contract — the caller must not mutate it until the stream
    resolves), the committed chunk geometry, and the pump cursor.  Lives
    in ``Dispatcher._active_streams`` while chunks remain to post; the
    slot's :class:`_TxRec` points back here so poll outcomes (OK /
    REJECTED / NACK) can stop or restart the pump."""

    handle: object
    payload: memoryview
    desc: F.StreamDesc
    codec: object            # negotiated wire codec (None -> raw)
    peer: "Peer"
    lane: "RingState"
    abs_slot: int
    cells_base: int          # slot offset of cell 0 (header + code + desc)
    corr_id: int = 0
    future: object = None
    next_send: int = 0       # chunks posted so far (the pump cursor)
    dead: bool = False       # NACKed/rejected/failed: pump must not touch
    #                          the slot again (a restart revives the tx)


class _StreamResend:
    """Queued FULL re-open of a NACKed SLIM stream.  Rides ``peer.resend``
    next to IfuncMsg retransmits (the type check in ``_flush_resends``
    dispatches); ``corr_id`` mirrors the tx so the fail-path's queued-
    retransmit drop resolves its future like any other entry."""

    __slots__ = ("tx", "corr_id")

    def __init__(self, tx: _StreamTx):
        self.tx = tx
        self.corr_id = tx.corr_id


@dataclass(slots=True)
class _PendingSub:
    """One coalesced invocation awaiting (or riding) an aggregate: the
    materialized payload plus everything a FULL-singleton rebuild needs.
    name/kind/digest are copied out of the handle's library at enqueue so
    the pack loop reads plain slots — and the attribute protocol matches
    :class:`frame.AggSub`, so ``seal_agg_frame`` packs these directly
    (no intermediate wire-object per record)."""

    handle: object
    name: str
    kind: object
    digest: bytes
    payload: bytes
    corr_id: int
    cont: bytes | None
    future: object
    enq_at: float
    err: bool = False       # request records never carry the reply-err bit


class _CoalesceQ:
    """One (peer, ring)'s pending sub-records with an exact running byte
    count of the aggregate frame they would pack into."""

    __slots__ = ("subs", "names", "bytes")

    #: header + sub/name counts + aggregate signal + frame trailer
    BASE = F.HEADER_LEN + 4 + 4 + F.TRAILER_LEN

    def __init__(self):
        self.subs: list[_PendingSub] = []
        self.names: set[str] = set()
        self.bytes = self.BASE

    def would_take(self, sub: _PendingSub) -> int:
        extra = (F.AGG_SUB_OVERHEAD + len(sub.payload)
                 + (0 if sub.cont is None else len(sub.cont)))
        if sub.name not in self.names:
            # ifunc names are policy-constrained ASCII: len == byte length
            extra += 1 + len(sub.name)
        return self.bytes + extra

    def add(self, sub: _PendingSub) -> None:
        self.bytes = self.would_take(sub)
        self.names.add(sub.name)
        self.subs.append(sub)


@dataclass
class RingState:
    """One (mailbox, channel) lane of a peer."""

    mailbox: object
    channel: object
    tail: int = 0            # source-side produce index
    inflight: dict = field(default_factory=dict)   # abs slot -> _TxRec
    corr_by_coords: dict = field(default_factory=dict)  # device lanes:
    #                                    (shard, slot) -> (corr_id, sent_at)
    #                                    awaiting a sweep result
    agg_by_coords: dict = field(default_factory=dict)  # device lanes:
    #                                    (shard, slot) -> _TxRec of a staged
    #                                    aggregate container (device frames
    #                                    have no slot->inflight tracking; the
    #                                    sweep reports by coordinates)

    @property
    def credits(self) -> int:
        return self.mailbox.n_slots - (self.tail - self.mailbox.consumed)


@dataclass
class Peer:
    name: str
    fabric: Fabric
    target_ctx: object
    target_args: dict
    rings: list[RingState] = field(default_factory=list)
    cached: set = field(default_factory=set)       # digests confirmed cached
    resend: deque = field(default_factory=deque)   # FULL msgs queued post-NACK
    coalesce: dict = field(default_factory=dict)   # ring key -> _CoalesceQ of
    #                                  sub-records awaiting an aggregate flush
    stripe: bool = False           # multi-ring striping: posts rotate across
    #                                  rings instead of greedy credit-max
    stripe_tx: int = 0             # next ring to post into (mod len(rings))
    stripe_rx: int = 0             # next ring to consume from — strict TX==RX
    #                                  rotation keeps per-peer FIFO across M
    #                                  rings with ONE demux (the reply ring
    #                                  and resend queue stay per-peer)
    codec: object = None           # negotiated wire codec for streamed sends
    #                                  (frame v2.5; None -> raw chunks)
    reply_mailbox: object = None   # source-owned ring the target replies into
    reply_channel: object = None   # target->source path into it
    reply_tail: int = 0            # target-side produce index for replies
    fence: int = 0                 # generation fence: replies whose corr was
    #                                  allocated under an earlier fleet
    #                                  generation (corr_gen < fence) are
    #                                  resurrection attempts from this peer's
    #                                  previous life — dropped + counted as
    #                                  fenced_orphans.  Stamped at
    #                                  re-admission; 0 = never fenced.
    stats: dict = field(
        default_factory=lambda: dict.fromkeys(_PEER_STAT_KEYS, 0))

    def reset_stats(self) -> None:
        """Zero every counter in place (the dict identity is aliased into
        the obs registry and shared with callers — never replace it)."""
        for k in _PEER_STAT_KEYS:
            self.stats[k] = 0

    @property
    def credits(self) -> int:
        return sum(r.credits for r in self.rings)

    @property
    def reply_credits(self) -> int:
        if self.reply_mailbox is None:
            return 0
        return self.reply_mailbox.n_slots - (self.reply_tail
                                             - self.reply_mailbox.consumed)

    def oldest_inflight_age(self, now: float | None = None) -> float:
        """Age (seconds) of the oldest tracked frame still awaiting its
        target's sweep — the liveness floor signal.  0.0 when nothing is
        in flight.  Covers handle sends on host lanes and corr-carrying
        stages on device lanes (``corr_by_coords``), so a wedged mesh is
        as visible as a wedged host ring."""
        now = time.monotonic() if now is None else now
        oldest = None
        for r in self.rings:
            for slot, rec in r.inflight.items():
                if slot < r.mailbox.consumed:
                    continue            # consumed by an external sweeper
                if oldest is None or rec.sent_at < oldest:
                    oldest = rec.sent_at
            for _, sent_at in r.corr_by_coords.values():
                if oldest is None or sent_at < oldest:
                    oldest = sent_at
            for rec in r.agg_by_coords.values():
                if oldest is None or rec.sent_at < oldest:
                    oldest = rec.sent_at
        return 0.0 if oldest is None else max(0.0, now - oldest)

    def summary(self) -> str:
        s = self.stats
        agg = (f" agg={s['agg_sent']}x{s['agg_subs'] / s['agg_sent']:.1f}"
               if s.get("agg_sent") else "")
        return (f"{self.name:<12s} fabric={self.fabric.kind:<9s} "
                f"sent={s['sent']:<4d} slim={s['slim_sent']:<4d} "
                f"delivered={s['delivered']:<4d} "
                f"rejected={s['rejected']:<3d} nacks={s['nacks']:<3d} "
                f"backpressure={s['backpressure']:<3d} "
                f"replies={s['replies']:<4d} "
                f"credits={self.credits}{agg}")


class Dispatcher:
    """One source fanning ifunc frames out to heterogeneous targets."""

    def __init__(self, src_ctx=None, engine: ProgressEngine | None = None, *,
                 coalesce: bool = False, obs: Obs | None = None):
        self.src_ctx = src_ctx
        self.engine = engine if engine is not None else ProgressEngine()
        self.peers: dict[str, Peer] = {}
        self._rr = 0             # fairness cursor over (peer, ring) lanes
        self.stats = {"sent": 0, "polled": 0, "poll_rounds": 0, "nacks": 0,
                      "replies": 0, "reply_dropped": 0, "agg_sent": 0,
                      "streams": 0, "timed_out": 0}
        # observability bundle: counters + flight recorder by default,
        # span tracing when the caller opted in (Obs(trace=True)).  One
        # bundle is shared across the dispatcher, its engine, and every
        # peer's target context, so cross-peer traces land in one file.
        self.obs = obs if obs is not None else Obs("dispatcher")
        self.obs.metrics.register_dict("dispatcher", self.stats)
        if getattr(self.engine, "obs", None) is None:
            self.engine.obs = self.obs
            self.obs.metrics.register_dict("engine", self.engine.stats)
        # task-runtime hooks (see repro.tasks): the router receives
        # (corr_id, name, value, is_err, decoded); the codec provides
        # encode(value)->bytes / encode_error(exc)->bytes for reply frames
        self.reply_router = None
        self.reply_codec = None
        self._coalesce = False
        self._agg_max_subs = 16
        self._agg_max_age = 5e-4
        self._agg_max_sub_bytes = 16 << 10
        self._streaming = False
        self._stream_chunk = 256 << 10
        self._stream_window = 4
        self._stream_threshold = None    # None -> _agg_max_sub_bytes
        self._stream_nonce = 0           # monotone: unique per stream open
        self._active_streams: list[_StreamTx] = []
        self._sweep_raise = None   # deferred mid-batch ifunc exception (a
        #       corr-less poisoned slot behind already-swept frames): poll
        #       re-raises it only after processing those frames' statuses
        self.faults = None       # FaultInjector: consulted (when set) at the
        #       poll loop (down peers stop being swept), the post point
        #       (k-th-put drops), and the ElasticController's beat pump
        self.pollers: list = []  # side-band callables invoked at every
        #       poll() entry — the ElasticController rides here so
        #       heartbeats pump/sweep on the same cadence as data traffic
        if coalesce:
            self.set_coalescing(True)

    def set_coalescing(self, enabled: bool = True, *, max_subs: int = 16,
                       max_age: float = 5e-4,
                       max_sub_bytes: int = 16 << 10) -> None:
        """Turn coalesced dispatch on/off.  ``max_subs`` caps sub-records
        per aggregate (an enqueue reaching it flushes immediately, so a
        steady burst ships in full containers); ``max_age`` (seconds)
        bounds how long the oldest queued record may wait before a poll
        force-flushes its queue — the adaptive knob that keeps a trickle
        workload's latency within one poll of the singleton path.
        ``max_sub_bytes`` bounds the payload size worth aggregating:
        coalescing amortizes *per-message* protocol overhead, and past a
        few KiB the wire is bandwidth-bound — bigger records bypass the
        queue and ship as plain SLIM singletons (after flushing anything
        queued ahead of them, so FIFO holds)."""
        if max_subs < 1:
            raise TransportError(f"max_subs must be >= 1, got {max_subs}")
        self._coalesce = enabled
        self._agg_max_subs = max_subs
        self._agg_max_age = max_age
        self._agg_max_sub_bytes = max_sub_bytes

    def set_streaming(self, enabled: bool = True, *,
                      chunk_bytes: int = 256 << 10, window: int = 4,
                      threshold: int | None = None) -> None:
        """Turn streamed large-payload dispatch on/off.  ``chunk_bytes`` is
        the per-chunk put size (clamped per lane so ``window`` cells plus
        the FULL-fallback prefix fit one ring slot), ``window`` the
        pipelining depth (chunks in flight past the target's consume
        cursor), ``threshold`` the payload size above which
        ``send_ifunc``/coalesced sends auto-route into the stream path
        (None: the coalescing bypass bound ``max_sub_bytes``, so the
        store-and-forward singleton cliff disappears exactly where the
        bypass used to ship it)."""
        if chunk_bytes < 1:
            raise TransportError(f"chunk_bytes must be >= 1, got {chunk_bytes}")
        if window < 1:
            raise TransportError(f"window must be >= 1, got {window}")
        self._streaming = enabled
        self._stream_chunk = chunk_bytes
        self._stream_window = window
        self._stream_threshold = threshold

    @property
    def _stream_thr(self) -> int:
        t = self._stream_threshold
        return self._agg_max_sub_bytes if t is None else t

    # -- topology -----------------------------------------------------------

    def add_peer(self, name: str, fabric: Fabric, target_ctx, *,
                 n_slots: int = DEFAULT_N_SLOTS,
                 slot_size: int = DEFAULT_SLOT_SIZE,
                 rings: int = 1, stripe: bool = False,
                 target_args: dict | None = None,
                 codec=None, **mailbox_kw) -> Peer:
        """``mailbox_kw`` passes backend-specific binds through to
        ``fabric.open_mailbox`` (e.g. ``prog=``/``externals=`` on the
        device-mesh fabric).  ``stripe=True`` (with ``rings > 1``) stripes
        the peer's traffic round-robin across its rings under one demux:
        sends rotate strictly (blocking on the rotation ring's credits
        rather than skipping ahead) and the poll consumes in the same
        rotation, so per-peer FIFO holds while a hot peer's slot budget
        scales with M rings.  Striped peers accept ``ring=None`` sends
        only — an explicit ring index would punch holes in the rotation.
        ``codec`` (id, name, or Codec) negotiates the wire codec streamed
        sends to this peer encode their chunks with (frame v2.5)."""
        if name in self.peers:
            raise TransportError(f"peer {name!r} already attached")
        peer = Peer(name, fabric, target_ctx,
                    target_args if target_args is not None else {})
        if codec is not None:
            c = WC.get_codec(codec)
            peer.codec = None if c.id == WC.RAW else c
        for _ in range(rings):
            mb = fabric.open_mailbox(target_ctx, n_slots, slot_size,
                                     **mailbox_kw)
            ch = fabric.connect(self.src_ctx, mb)
            peer.rings.append(RingState(mb, ch))
        peer.stripe = stripe and rings > 1
        self.peers[name] = peer
        self.obs.metrics.register_dict(f"peer.{name}", peer.stats)
        if (target_ctx is not None
                and getattr(target_ctx, "obs", None) is None
                and hasattr(target_ctx, "obs")):
            # share the bundle with the target side: execute/sweep spans
            # land in the same trace as the source's put spans
            target_ctx.obs = self.obs
        return peer

    def set_peer_codec(self, name: str, codec) -> None:
        """(Re-)negotiate the wire codec streamed sends to ``name`` encode
        their chunks with — the runtime half of codec negotiation: the
        decode side *advertises* accepted codecs in its admission ack and
        the sender arms the winner here, instead of baking one in at
        ``add_peer`` time.  Safe while streams are idle; an in-flight
        stream keeps the codec it opened with (``_StreamTx`` snapshots
        it), so a renegotiation never splits one payload across codecs."""
        peer = self.peers[name]
        c = WC.get_codec(codec)
        peer.codec = None if c.id == WC.RAW else c

    def attach_reply_ring(self, name: str, mailbox, channel) -> None:
        """Give a host peer a result-return path: ``mailbox`` is a
        source-owned ring (opened on the *source* context), ``channel`` the
        target->source path into it.  Corr-carrying requests executed at
        this peer post their outputs here as FLAG_REPLY frames; device-mesh
        peers need none (sweep results are correlated directly)."""
        peer = self.peers[name]
        if peer.fabric.kind == "device":
            raise TransportError(
                "device-mesh peers reply through the sweep, not a ring")
        peer.reply_mailbox = mailbox
        peer.reply_channel = channel
        peer.reply_tail = 0

    def remove_peer(self, name: str) -> None:
        """Cleanly retire a peer: release its slab-backed channels, drop
        queued coalesced sub-records and NACK retransmits, clear stripe
        rotation and in-flight tracking, and unregister its obs alias (so a
        re-admitted peer's stats dict reclaims ``peer.<name>`` instead of
        landing under a uniquified suffix).  Idempotent — recovery paths
        (controller deadline, explicit teardown, tests) may race to call it.
        Does NOT resolve in-flight futures; call :meth:`fail_inflight`
        (scoped via ``peers={name}``) *before* removal if the peer died
        with work outstanding."""
        peer = self.peers.pop(name, None)
        if peer is None:
            return
        for r in peer.rings:
            self.engine.release_slab(r.channel)
            r.inflight.clear()
            r.corr_by_coords.clear()
            r.agg_by_coords.clear()
        if peer.reply_channel is not None:
            self.engine.release_slab(peer.reply_channel)
        peer.resend.clear()
        for q in peer.coalesce.values():
            q.subs.clear()
        peer.coalesce.clear()
        peer.stripe_tx = peer.stripe_rx = 0
        self._active_streams = [tx for tx in self._active_streams
                                if tx.peer is not peer]
        self.obs.metrics.unregister_dict(f"peer.{name}", peer.stats)
        self._rr = 0             # lane list shrank: restart the fair cursor

    # -- source side --------------------------------------------------------

    def _slim_ok(self, peer: Peer, lib) -> bool:
        """SLIM-eligible: device lanes link at mailbox-open time (code never
        travels); host lanes need a confirmed FULL delivery of this digest."""
        if peer.fabric.kind == "device":
            return True
        return lib.code_digest in peer.cached

    def _check_full_fits(self, lane: RingState, lib, payload_len: int,
                         cont_len: int = 0) -> None:
        """A SLIM frame must stay FULL-retransmittable: if the target evicts
        the digest, the NACK fallback rebuilds code + payload (+ any
        continuation descriptor) into this same ring — reject at send time
        rather than crash a later drain."""
        need = (F.HEADER_LEN + len(lib.code) + payload_len + cont_len
                + F.TRAILER_LEN)
        if need > lane.mailbox.slot_size:
            raise TransportError(
                f"SLIM frame's FULL fallback ({need}B) exceeds slot "
                f"{lane.mailbox.slot_size}B — NACK retransmit impossible")

    def _agg_eligible(self, peer: Peer) -> bool:
        """Aggregate-container eligible: host lanes always; device lanes
        only when their mailboxes were opened agg-bound (``agg_k=`` — the
        put transcodes a container into a K-sub word-frame and the sweep
        executes all K per ring visit)."""
        if peer.fabric.kind != "device":
            return True
        return all(getattr(r.mailbox, "supports_agg", False)
                   for r in peer.rings)

    def _pick_lane(self, peer: Peer, ring: int | None) -> RingState | None:
        if peer.stripe and ring is None:
            # strict rotation: block on the rotation ring's credits rather
            # than skip ahead — a skip would reorder the peer's frames
            lane = peer.rings[peer.stripe_tx % len(peer.rings)]
            return lane if lane.credits > 0 else None
        lanes = peer.rings if ring is None else [peer.rings[ring]]
        lane = max(lanes, key=lambda r: r.credits)
        return lane if lane.credits > 0 else None

    def _bp(self, peer: Peer) -> None:
        """Count (and flight-record) one backpressure event."""
        peer.stats["backpressure"] += 1
        if self.obs.enabled:
            self.obs.recorder.add("backpressure", peer.name,
                                  f"credits={peer.credits}")

    @staticmethod
    def _check_ring_kw(peer: Peer, ring: int | None) -> None:
        if ring is not None and peer.stripe:
            raise TransportError(
                f"striped peer {peer.name!r} accepts ring=None sends only "
                "(an explicit ring would punch a hole in the rotation)")

    def _post_view(self, peer: Peer, lane: RingState, view, rec, on_complete,
                   future=None):
        o = self.obs
        if o.enabled and rec is not None:
            o.recorder.add("put", peer.name,
                           f"{rec.name} corr={rec.corr_id} {len(view)}B"
                           f"{' slim' if rec.slim else ''}")
            if (o.tracer.enabled and rec.span is None
                    and peer.fabric.kind != "device"):
                # the wire span: post -> delivery confirmation (poll OK),
                # NACK, or reject — ended where the inflight record pops
                rec.span = o.tracer.begin(
                    f"put:{rec.name}@{peer.name}", cat="wire",
                    actor=getattr(self.src_ctx, "name", "source"),
                    corr=rec.corr_id or None, bytes=len(view))
        if (self.faults is not None
                and self.faults.should_drop_put(peer.name)):
            # injected wire loss: the source's bookkeeping proceeds exactly
            # as if the put landed (tx record, tail advance, stripe
            # rotation, stats) but the bytes never reach the target — the
            # frame is recovered only when the liveness deadline fires
            # fail_inflight, same as a genuinely lost put
            peer.stats["dropped_puts"] += 1
            if o.enabled:
                o.recorder.add("drop_put", peer.name,
                               f"{rec.name if rec else '?'} slot={lane.tail}")
        else:
            self.engine.post(lane.channel, view, lane.tail, peer=peer.name,
                             on_complete=on_complete, future=future)
        if rec is not None and peer.fabric.kind != "device":
            lane.inflight[lane.tail] = rec
            if len(lane.inflight) > 2 * lane.mailbox.n_slots:
                # target sweeps outside our poll loop (e.g. WorkerAgent):
                # drop records for slots already consumed elsewhere
                low = lane.mailbox.consumed
                for s in [s for s in lane.inflight if s < low]:
                    del lane.inflight[s]
        if (rec is not None and rec.corr_id
                and peer.fabric.kind == "device"):
            # device replies come back as sweep results at the coordinates
            # this send stages into (the Mailbox.slot_coords contract)
            lane.corr_by_coords[lane.mailbox.slot_coords(lane.tail)] = (
                rec.corr_id, rec.sent_at)
        if (rec is not None and rec.subs is not None
                and peer.fabric.kind == "device"):
            # device aggregates complete by coordinates: the sweep leaves
            # per-sub outcomes in Mailbox.last_agg keyed the same way
            lane.agg_by_coords[lane.mailbox.slot_coords(lane.tail)] = rec
        lane.tail += 1
        if peer.stripe and lane is peer.rings[
                peer.stripe_tx % len(peer.rings)]:
            peer.stripe_tx += 1          # rotation advances at the ONE post
            #                              point, so every path (singleton,
            #                              aggregate, resend) rotates
        peer.stats["sent"] += 1
        peer.stats["bytes"] += len(view)
        if rec is not None and rec.slim:
            peer.stats["slim_sent"] += 1
        self.stats["sent"] += 1

    def _slab_post(self, peer: Peer, lane: RingState, frame, rec,
                   on_complete=None, future=None) -> None:
        """Stage a ready frame into the lane's slab cell and post it."""
        slab = self.engine.slab_slot(lane.channel, lane.tail)
        n = len(frame)
        if n > len(slab):
            raise TransportError(
                f"frame {n}B exceeds slot {lane.mailbox.slot_size}B")
        slab[:n] = frame
        self._post_view(peer, lane, slab[:n], rec, on_complete, future)

    def _flush_resends(self, peer: Peer) -> bool:
        """Post queued FULL retransmits (NACK fallback) ahead of any new
        traffic; False while the queue cannot drain.

        Retransmits are held until the peer's rings are quiescent (every
        in-flight frame resolved): an eviction NACKs *all* in-flight SLIM
        frames of the digest, but the NACKs surface one poll at a time —
        posting the first rebuild (or any newer frame) before the rest have
        reported would reorder execution at the target.  Waiting for
        quiescence makes the resend queue a faithful replay of ring order,
        so per-peer FIFO survives eviction storms."""
        if not peer.resend:
            return True
        if any(r.tail != r.mailbox.consumed for r in peer.rings):
            return False                       # storm not fully observed yet
        while peer.resend:
            lane = self._pick_lane(peer, None)
            if lane is None:
                return False
            msg = peer.resend.popleft()
            o = self.obs
            if isinstance(msg, _StreamResend):
                # NACKed SLIM stream: re-open FULL from chunk 0 under a
                # fresh nonce (the miss surfaced at the descriptor, before
                # any chunk was consumed — nothing replays; the nonce keeps
                # the dead open's still-racing chunk puts unmistakable)
                tx = msg.tx
                tx.desc = replace(tx.desc, nonce=self._next_nonce())
                if o.enabled:
                    o.recorder.add("resend", peer.name,
                                   f"stream {tx.handle.lib.name} "
                                   f"corr={tx.corr_id} FULL re-open")
                self._open_stream(peer, lane, tx, slim=False)
                peer.stats["resent"] += 1
                continue
            rec = _TxRec(msg.handle.lib.name, msg.handle.lib.code_digest,
                         msg.handle, slim=False,
                         corr_id=getattr(msg, "corr_id", 0))
            if o.enabled:
                o.recorder.add("resend", peer.name,
                               f"{rec.name} corr={rec.corr_id} FULL")
                if o.tracer.enabled:
                    # the retransmit is a child of the frame's logical
                    # lifetime: same corr as the NACKed wire span, its own
                    # interval under the "resend" category
                    rec.span = o.tracer.begin(
                        f"resend:{rec.name}@{peer.name}", cat="resend",
                        actor=getattr(self.src_ctx, "name", "source"),
                        corr=rec.corr_id or None)
            self._slab_post(peer, lane, msg.frame, rec)
            peer.stats["resent"] += 1
        return True

    # -- streamed large payloads (frame v2.5) --------------------------------

    def _next_nonce(self) -> int:
        self._stream_nonce += 1
        return self._stream_nonce & 0xFFFFFFFF

    def _stream_geometry(self, peer: Peer, lane: RingState, lib,
                         total: int, chunk: int, window: int) -> F.StreamDesc:
        """Commit a stream's chunk geometry, clamped so the frame — sized
        for its FULL fallback (header + code + descriptor + cells +
        trailer) — fits one ring slot even after a NACK rebuild restores
        the code section."""
        avail = (lane.mailbox.slot_size - F.HEADER_LEN - len(lib.code)
                 - F.STREAM_DESC_LEN - F.TRAILER_LEN)
        max_chunk = avail - F.CHUNK_OVERHEAD
        if max_chunk < 1:
            raise TransportError(
                f"slot {lane.mailbox.slot_size}B too small for even one "
                f"stream chunk cell past the {len(lib.code)}B code section")
        chunk = max(1, min(chunk, total, max_chunk))
        n_chunks = -(-total // chunk)
        window = max(1, min(window, n_chunks))
        while window > 1 and window * (chunk + F.CHUNK_OVERHEAD) > avail:
            window -= 1
        sflags = F.SFLAG_EXEC_ON_ARRIVAL if lib.streaming else 0
        codec_id = WC.RAW if peer.codec is None else peer.codec.id
        return F.StreamDesc(total, n_chunks, chunk, window, codec_id,
                            sflags, chunk + F.CHUNK_OVERHEAD,
                            self._next_nonce())

    @staticmethod
    def _encode_chunk(tx: _StreamTx, seq: int):
        """Codec-negotiated wire form of chunk ``seq``: (hdr, data, seal)
        where ``data`` is the codec output, or a zero-copy view into the
        payload when the codec is absent / doesn't shrink this chunk."""
        desc = tx.desc
        off = seq * desc.chunk_bytes
        raw = tx.payload[off:off + desc.chunk_bytes]
        # chunk 0 ships bit-exact under a lossy codec: the payload prefix
        # carries routing fields arrival-executing ifuncs peek at
        skip = tx.codec is None or (seq == 0 and tx.codec.lossy)
        coded = None if skip else tx.codec.encode(raw)
        if coded is None:
            data, used = raw, WC.RAW
        else:
            data, used = coded, tx.codec.id
        hdr, seal = F.pack_chunk_hdr(seq, len(data), len(raw), used,
                                     nonce=desc.nonce)
        return hdr, data, seal

    def _open_stream(self, peer: Peer, lane: RingState, tx: _StreamTx, *,
                     slim: bool) -> None:
        """Post a stream's open.  When every chunk fits the frame's cell
        window (``n_chunks <= window``), the whole frame — prefix, cells,
        trailer — goes out as ONE scatter-gather put (eager open; chunk
        data segments stay zero-copy views into the payload) and the
        stream never enters the chunk pump.  Otherwise: header + code +
        descriptor as one prefix put, the frame trailer withheld (the
        descriptor barrier), the ``window x cell`` gap never written, and
        the pump pipelines the chunks.  Either way the slot's
        :class:`_TxRec` carries the completion."""
        lib = tx.handle.lib
        code = b"" if slim else lib.code
        desc = tx.desc
        plen = F.stream_payload_len(desc.window, desc.cell)
        slab = self.engine.slab_slot(lane.channel, lane.tail)
        flen = F.seal_frame(slab, lib.name, code, lib.kind, plen,
                            digest=lib.code_digest, slim=slim,
                            corr_id=tx.corr_id, flags=F.FLAG_STREAM)
        F.pack_stream_desc(slab, F.HEADER_LEN + len(code), desc)
        prefix = F.HEADER_LEN + len(code) + F.STREAM_DESC_LEN
        tx.peer = peer
        tx.lane = lane
        tx.abs_slot = lane.tail
        tx.cells_base = prefix
        tx.next_send = 0
        tx.dead = False
        eager = desc.n_chunks <= desc.window
        if eager:
            # Eager open: chunk headers and seals stage INTO the slab at
            # their frame offsets, so every glue run (prefix|hdr,
            # seal|next-hdr, ...) that is byte-contiguous in the frame
            # collapses to one slab-view segment — for an uncompressed
            # stream the whole frame is [glue][data][glue][data]...[glue]
            # and the putv carries 2n+1 segments, the data ones zero-copy
            # views into the caller's payload.
            segs = []
            run_s, run_e = 0, prefix
            wire = prefix
            codec, nonce, chunk = tx.codec, desc.nonce, desc.chunk_bytes
            for seq in range(desc.n_chunks):
                cell = prefix + desc.cell_off(seq)
                raw = tx.payload[seq * chunk:(seq + 1) * chunk]
                skip = codec is None or (seq == 0 and codec.lossy)
                coded = None if skip else codec.encode(raw)
                if coded is None:
                    data, used = raw, WC.RAW
                else:
                    data, used = coded, codec.id
                nd = len(data)
                if cell != run_e:            # codec gap: run breaks here
                    segs.append((run_s, slab[run_s:run_e]))
                    run_s = cell
                run_e = cell + F.CHUNK_HDR_LEN
                F.pack_chunk_into(slab, cell, run_e + nd, seq, nd,
                                  len(raw), used, nonce=nonce)
                segs.append((run_s, slab[run_s:run_e]))
                segs.append((run_e, data))
                run_s = run_e + nd
                run_e = run_s + F.CHUNK_SEAL_LEN
                wire += F.CHUNK_OVERHEAD + nd
            segs.append((run_s, slab[run_s:run_e]))
            self.engine.post_stream_frame(lane.channel, lane.tail, segs,
                                          flen, peer=peer.name,
                                          future=tx.future)
            tx.next_send = desc.n_chunks
            peer.stats["bytes"] += wire + F.TRAILER_LEN
            peer.stats["stream_chunks"] += desc.n_chunks
        else:
            self.engine.post_stream_open(lane.channel, slab[:prefix], flen,
                                         lane.tail, peer=peer.name,
                                         future=tx.future)
            peer.stats["bytes"] += prefix + F.TRAILER_LEN
        rec = _TxRec(lib.name, lib.code_digest, tx.handle, slim,
                     corr_id=tx.corr_id, stream=tx)
        o = self.obs
        if o.enabled:
            o.recorder.add("stream_open", peer.name,
                           f"{lib.name} corr={tx.corr_id} "
                           f"{desc.total_len}B/{desc.n_chunks}ch"
                           f"{' eager' if eager else ''}"
                           f"{' slim' if slim else ''}")
            if o.tracer.enabled:
                rec.span = o.tracer.begin(
                    f"stream:{lib.name}@{peer.name}", cat="stream",
                    actor=getattr(self.src_ctx, "name", "source"),
                    corr=tx.corr_id or None, bytes=desc.total_len,
                    chunks=desc.n_chunks)
        lane.inflight[lane.tail] = rec
        lane.tail += 1
        peer.stats["sent"] += 1
        if slim:
            peer.stats["slim_sent"] += 1
        self.stats["sent"] += 1
        if eager:
            self.engine.flush(lane.channel)
        elif tx not in self._active_streams:
            self._active_streams.append(tx)

    def send_stream(self, peer_name: str, handle, payload, *,
                    ring: int | None = None, corr_id: int = 0, future=None,
                    chunk_bytes: int | None = None,
                    window: int | None = None) -> bool:
        """Stream one large payload to a host peer: ONE ring slot, ONE
        credit, the payload delivered as pipelined per-chunk puts instead
        of a store-and-forward frame bounded by the slot size.  The
        payload view must stay stable (unmutated) until the stream
        resolves — chunks are posted zero-copy straight from it.  SLIM
        framing, NACK FULL-rebuild, corr_id replies, and liveness
        (``fail_inflight``) work exactly as for singleton frames.
        Returns False on backpressure like any send."""
        peer = self.peers[peer_name]
        if peer.fabric.kind == "device":
            raise TransportError(
                "streams are host-tier only (the device mesh has no "
                "sub-slot addressing)")
        if peer.stripe:
            raise TransportError(
                f"striped peer {peer.name!r} cannot stream: a slot held "
                "across sweeps would wedge the strict consume rotation")
        pv = payload if isinstance(payload, memoryview) \
            else memoryview(payload)
        if pv.ndim != 1 or pv.itemsize != 1:
            pv = pv.cast("B")
        total = len(pv)
        if total == 0:
            raise TransportError("cannot stream an empty payload")
        if not self._flush_resends(peer):
            self._bp(peer)
            return False
        if not self._flush_coalesce_peer(peer):
            self._bp(peer)                    # FIFO: queued records go first
            return False
        lane = self._pick_lane(peer, ring)
        if lane is None:
            self._bp(peer)
            return False
        lib = handle.lib
        desc = self._stream_geometry(
            peer, lane, lib, total,
            self._stream_chunk if chunk_bytes is None else chunk_bytes,
            self._stream_window if window is None else window)
        tx = _StreamTx(handle, pv, desc, peer.codec, peer, lane, lane.tail,
                       0, corr_id=corr_id, future=future)
        self._open_stream(peer, lane, tx, slim=self._slim_ok(peer, lib))
        peer.stats["streams"] += 1
        self.stats["streams"] += 1
        self._pump_streams()
        return True

    def _pump_streams(self) -> int:
        """Advance every active stream: post chunks (codec-encoded when
        the negotiated codec shrinks them, raw otherwise) while the
        window is open — at most ``window`` chunks past the target's
        consume cursor — then flush the touched channels so the seals
        publish.  Fully-posted streams leave the pump; their slot's
        _TxRec carries the completion."""
        if not self._active_streams:
            return 0
        posted = 0
        flushes: dict[int, object] = {}
        still: list[_StreamTx] = []
        for tx in self._active_streams:
            if tx.dead:
                continue
            desc = tx.desc
            mb = tx.lane.mailbox
            coords = mb.slot_coords(tx.abs_slot)
            peer, channel = tx.peer, tx.lane.channel
            before = tx.next_send
            while tx.next_send < desc.n_chunks:
                if tx.next_send - mb.stream_consumed(coords) >= desc.window:
                    break                # window closed: cell still in use
                seq = tx.next_send
                hdr, data, seal = self._encode_chunk(tx, seq)
                self.engine.post_chunk(
                    channel, tx.abs_slot, tx.cells_base + desc.cell_off(seq),
                    hdr, data, seal, peer=peer.name)
                tx.next_send += 1
                posted += 1
                peer.stats["bytes"] += len(hdr) + len(data) + len(seal)
                peer.stats["stream_chunks"] += 1
            if tx.next_send > before:
                flushes[id(channel)] = channel
            if tx.next_send < desc.n_chunks:
                still.append(tx)
        self._active_streams = still
        for ch in flushes.values():
            self.engine.flush(ch)
        return posted

    # -- coalesced dispatch (frame v2.3 aggregates) --------------------------

    @staticmethod
    def _materialize_payload(lib, source_args, source_args_size) -> bytes:
        """Run the library's payload codec into a scratch buffer.  A
        coalesced record cannot write straight into a slab cell (its final
        offset inside the aggregate is unknown until flush), so small
        payloads pay one copy here — the per-frame header/signal/trailer
        amortization is worth orders of magnitude more at the sizes
        coalescing targets."""
        if source_args_size is None:
            try:
                source_args_size = len(source_args)
            except TypeError:
                source_args_size = 0
        max_size = int(lib.payload_get_max_size(source_args, source_args_size))
        buf = bytearray(max_size)
        used = lib.payload_init(memoryview(buf), max_size, source_args,
                                source_args_size)
        used = max_size if used in (None, 0) else int(used)
        return bytes(memoryview(buf)[:used])

    def _enqueue_sub(self, peer: Peer, handle, source_args, source_args_size,
                     ring, corr_id, future, cont) -> bool:
        """Queue one cache-warm invocation for aggregate packing (no ring
        credit is claimed until flush); flushes the queue first when this
        record would overflow the slot byte budget, and after adding when
        the sub-record cap fills.  The queue is bounded at a full ring's
        worth of containers (``max_subs * n_slots`` records): past that,
        with flushes backpressured, the send reports False like any
        credit-starved send — a producer outrunning its consumer is
        throttled, not buffered without bound."""
        lib = handle.lib
        lane0 = peer.rings[ring if ring is not None else 0]
        q0 = peer.coalesce.get(ring)
        if (q0 is not None and len(q0.subs)
                >= self._agg_max_subs * lane0.mailbox.n_slots):
            self._flush_coalesce_peer(peer, ring)
            q0 = peer.coalesce.get(ring)
            if (q0 is not None and len(q0.subs)
                    >= self._agg_max_subs * lane0.mailbox.n_slots):
                self._bp(peer)
                return False
        payload = self._materialize_payload(lib, source_args,
                                            source_args_size)
        if peer.fabric.kind != "device":
            # the NACK fallback rebuilds this record as a FULL singleton
            # into the same ring — reject now rather than crash a later
            # drain (device lanes size their slots for the bound word-frame
            # plus code that never travels: the check does not apply)
            self._check_full_fits(lane0, lib, len(payload),
                                  0 if cont is None else len(cont))
        sub = _PendingSub(handle, lib.name, lib.kind, lib.code_digest,
                          payload, corr_id, cont, future, time.monotonic())
        if (self._streaming and len(payload) > self._stream_thr
                and cont is None and peer.fabric.kind != "device"
                and not peer.stripe):
            # oversized record with streaming on: the slot-bounded bypass
            # singleton is the 64KiB cliff — stream it instead (send_stream
            # flushes queued records first, so FIFO holds)
            return self.send_stream(peer.name, handle, payload, ring=ring,
                                    corr_id=corr_id, future=future)
        if len(payload) > self._agg_max_sub_bytes:
            # bandwidth-bound record: aggregation buys nothing — ship it
            # as a plain SLIM singleton, after anything queued before it
            if not self._flush_coalesce_peer(peer, ring):
                self._bp(peer)
                return False
            lane = self._pick_lane(peer, ring)
            if lane is None:
                self._bp(peer)
                return False
            self._post_agg(peer, lane, [sub])
            return True
        q = peer.coalesce.get(ring)
        if q is None:
            q = peer.coalesce[ring] = _CoalesceQ()
        cap = lane0.mailbox.slot_size
        if q.subs and q.would_take(sub) > cap:
            self._flush_coalesce_peer(peer, ring)      # slot budget filled
            q = peer.coalesce.get(ring)
            if q is None:
                q = peer.coalesce[ring] = _CoalesceQ()
        q.add(sub)
        peer.stats["coalesced"] += 1
        if len(q.subs) >= self._agg_max_subs or q.bytes > cap:
            self._flush_coalesce_peer(peer, ring)      # cap (or lone record
            #                    too big to share a container): best-effort
            #                    flush now; on backpressure it stays queued
        return True

    def send_ifunc_many(self, peer_name: str, handle, payloads, *,
                        ring: int | None = None, corr_ids=None,
                        futures=None) -> int:
        """Bulk coalescing enqueue: K invocations of one handle in one
        call, with the payload codec, digest, and queue state hoisted out
        of the per-record loop — the per-call interpreter overhead that
        dominates a small-message burst is paid once per batch, not once
        per message.  ``corr_ids``/``futures`` (parallel lists) tie
        records to the task runtime's reply path.  Returns the number of
        records accepted, stopping early at a record it cannot accept —
        backpressure on a bypass record, or a record whose FULL fallback
        would not fit a ring slot (retrying the remainder through
        :meth:`send_ifunc` surfaces the hard error for that record).
        Falls back to per-record :meth:`send_ifunc` when coalescing is
        off or the peer is not aggregate-eligible."""
        peer = self.peers[peer_name]
        self._check_ring_kw(peer, ring)
        lib = handle.lib
        if not (self._coalesce and self._agg_eligible(peer)
                and self._slim_ok(peer, lib)):
            n = 0
            for i, args in enumerate(payloads):
                if not self.send_ifunc(
                        peer_name, handle, args, ring=ring,
                        corr_id=corr_ids[i] if corr_ids else 0,
                        future=futures[i] if futures else None):
                    break
                n += 1
            return n
        is_device = peer.fabric.kind == "device"
        lane0 = peer.rings[ring if ring is not None else 0]
        cap = lane0.mailbox.slot_size
        agg_k = getattr(lane0.mailbox, "agg_k", 0)
        full_base = F.HEADER_LEN + len(lib.code) + F.TRAILER_LEN
        gms, init = lib.payload_get_max_size, lib.payload_init
        name, kind, digest = lib.name, lib.kind, lib.code_digest
        max_subs = min(self._agg_max_subs, agg_k) if agg_k \
            else self._agg_max_subs
        max_sub_bytes = self._agg_max_sub_bytes
        now = time.monotonic()
        payloads = payloads if isinstance(payloads, (list, tuple)) \
            else list(payloads)
        N = len(payloads)
        n = i = 0
        q = peer.coalesce.get(ring)

        # -- direct slab pack: with nothing queued ahead (FIFO safe) and a
        # -- ring slot free, each record's payload codec writes STRAIGHT
        # -- into the slab cell at its final aggregate offset (the v2.4
        # -- columnar layout streams payloads first; the fixed headers
        # -- settle as one table write at finish) — no scratch buffer, no
        # -- second copy, no per-record queue bookkeeping
        if (q is None or not q.subs) and self._flush_resends(peer):
            sub_fixed = F.AGG_SUB_OVERHEAD
            kind_int = int(kind)
            while i < N:
                # peek the head record BEFORE touching the slab: a
                # bypass-sized head ships as a SLIM singleton and must not
                # pay for a container prologue it will never use
                args = payloads[i]
                try:
                    sz = len(args)
                except TypeError:
                    sz = 0
                mx = int(gms(args, sz))
                if (self._streaming and not is_device and not peer.stripe
                        and mx > self._stream_thr):
                    break                # oversized head: the generic loop
                #                          routes it into the stream path
                if not is_device and full_base + mx > cap:
                    break                # FULL fallback cannot fit a ring
                #                          slot: the generic loop errors
                lane = self._pick_lane(peer, ring)
                if lane is None:
                    break                # no credits: queue the remainder
                slab = self.engine.slab_slot(lane.channel, lane.tail)
                view = F.frame_payload_view(
                    slab, 0, len(slab) - F.HEADER_LEN - F.TRAILER_LEN)
                if mx > max_sub_bytes:
                    # bandwidth-bound record: aggregation buys nothing, so
                    # it ships as a SLIM singleton packed straight into
                    # the slab — the codec writes in place and seal_frame
                    # wraps around it, no scratch materialization, no
                    # queue round-trip (the bypass-parity contract:
                    # records the policy declines to aggregate pay
                    # singleton cost, not singleton + coalescing-
                    # machinery cost)
                    used = init(view[:mx], mx, args, sz)
                    used = mx if used in (None, 0) else int(used)
                    cid = corr_ids[i] if corr_ids else 0
                    fl = F.seal_frame(slab, name, b"", kind, used,
                                      digest=digest, slim=True,
                                      corr_id=cid)
                    self._post_view(peer, lane, slab[:fl],
                                    _TxRec(name, digest, handle,
                                           slim=True, corr_id=cid),
                                    None,
                                    futures[i] if futures else None)
                    n += 1
                    i += 1
                    continue             # slot consumed: repick a lane
                off = F.begin_agg(view, [name])
                prologue_end = off
                hdrs: list[tuple] = []
                subs: list[_PendingSub] = []
                hdr_add, sub_add = hdrs.append, subs.append
                budget = len(view) - 4
                n_subs = 0
                stop = False
                # the inner loop IS the per-message cost of a coalesced
                # burst: the sub-header row is built inline (plain
                # records: name_idx 0, no flags, no cont) and the payload
                # view is sliced once when the codec fills its estimate
                while i < N and n_subs < max_subs:
                    args = payloads[i]
                    try:
                        sz = len(args)
                    except TypeError:
                        sz = 0
                    mx = int(gms(args, sz))
                    if not is_device and full_base + mx > cap:
                        stop = True      # FULL fallback cannot fit a ring
                        break            # slot: the generic loop errors
                    if mx > max_sub_bytes:
                        break            # seal the container first; the
                        #                  outer peek re-sees this record
                    n_subs += 1
                    if off + mx + n_subs * sub_fixed > budget:
                        n_subs -= 1
                        break            # container full: seal + continue
                    pv = view[off:off + mx]
                    used = init(pv, mx, args, sz)
                    used = mx if used in (None, 0) else int(used)
                    cid = corr_ids[i] if corr_ids else 0
                    hdr_add((0, kind_int, 0, digest, cid, used, 0))
                    sub_add(_PendingSub(
                        handle, name, kind, digest,
                        pv if used == mx else view[off:off + used],
                        cid, None, futures[i] if futures else None, now))
                    off += used
                    i += 1
                if not subs:
                    break
                plen = F.finish_agg(view, prologue_end, off, hdrs)
                fl = F.seal_frame(slab, F.AGG_NAME, b"", kind,
                                  plen, digest=F.NO_DIGEST, flags=F.FLAG_AGG)
                futs = [s.future for s in subs if s.future is not None]
                self._post_view(peer, lane, slab[:fl],
                                _TxRec(F.AGG_NAME, F.NO_DIGEST, None,
                                       slim=True, subs=subs),
                                None, futs or None)
                peer.stats["agg_sent"] += 1
                peer.stats["agg_subs"] += len(subs)
                peer.stats["coalesced"] += len(subs)
                self.stats["agg_sent"] += 1
                n += len(subs)
                if stop:
                    break

        # -- generic path: per-record through _enqueue_sub (records behind
        # -- an existing queue, bypass-sized records, backpressure
        # -- leftovers) — ONE implementation of the queueing policy
        while i < N:
            try:
                ok = self._enqueue_sub(peer, handle, payloads[i], None,
                                       ring,
                                       corr_ids[i] if corr_ids else 0,
                                       futures[i] if futures else None,
                                       None)
            except TransportError:
                break   # un-retransmittable record: stop here — the caller
                #         retries it through send_ifunc, which raises the
                #         TransportError with this record's identity
            if not ok:
                break   # queue bound hit with flushes backpressured
            i += 1
            n += 1
        return n

    def _post_agg(self, peer: Peer, lane: RingState,
                  subs: list[_PendingSub]) -> None:
        """Pack queued sub-records into the lane's slab cell and post: one
        container, one credit.  A single queued record ships as a plain
        SLIM singleton — the aggregate wrapper is never latency overhead."""
        if len(subs) == 1:
            sub = subs[0]
            lib = sub.handle.lib
            slab = self.engine.slab_slot(lane.channel, lane.tail)
            n = F.pack_frame_into(slab, lib.name, b"", sub.payload, lib.kind,
                                  digest=lib.code_digest, slim=True,
                                  corr_id=sub.corr_id, cont=sub.cont)
            self._post_view(peer, lane, slab[:n],
                            _TxRec(lib.name, lib.code_digest, sub.handle,
                                   slim=True, corr_id=sub.corr_id),
                            None, sub.future)
            return
        # _PendingSub speaks the AggSub attribute protocol: pack directly,
        # no intermediate wire object per record.  The container header
        # carries the records' code kind: the device put rejects non-UVM
        # frames at the header, before parsing the payload.
        slab = self.engine.slab_slot(lane.channel, lane.tail)
        n = F.seal_agg_frame(slab, subs, kind=subs[0].kind)
        futs = [s.future for s in subs if s.future is not None]
        rec = _TxRec(F.AGG_NAME, F.NO_DIGEST, None, slim=True,
                     subs=list(subs))
        o = self.obs
        if o.tracer.enabled and peer.fabric.kind != "device":
            # the container flush is its own span: the coalesced records'
            # submit spans (tasks layer) nest around it by corr
            rec.span = o.tracer.begin(
                f"agg:{len(subs)}@{peer.name}", cat="agg",
                actor=getattr(self.src_ctx, "name", "source"),
                subs=len(subs), bytes=n)
        self._post_view(peer, lane, slab[:n], rec, None, futs or None)
        peer.stats["agg_sent"] += 1
        peer.stats["agg_subs"] += len(subs)
        self.stats["agg_sent"] += 1

    @staticmethod
    def _split_budget(subs: list[_PendingSub], cap: int,
                      max_subs: int) -> int:
        """Longest prefix of ``subs`` that packs into ONE container within
        the slot byte budget and the record cap.  Always >= 1: a lone
        record posts as a SLIM singleton, whose fit ``_check_full_fits``
        guaranteed at enqueue."""
        names: set = set()
        total = _CoalesceQ.BASE
        n = 0
        for s in subs:
            extra = (F.AGG_SUB_OVERHEAD + len(s.payload)
                     + (0 if s.cont is None else len(s.cont)))
            if s.name not in names:
                extra += 1 + len(s.name)
            if n and (total + extra > cap or n >= max_subs):
                break
            total += extra
            names.add(s.name)
            n += 1
        return n

    def _flush_coalesce_peer(self, peer: Peer,
                             ring: int | None = "all") -> bool:
        """Drain a peer's coalescing queue(s) into aggregate posts,
        splitting into as many containers as the slot budget requires —
        the enqueue-side byte count is only a flush *trigger*; a queue
        that overgrew while a flush was backpressured still drains
        correctly, one slot-sized container at a time.  False when a
        queue could not fully drain (no ring credits) — its remaining
        records stay queued, in order, for the next attempt."""
        if not peer.coalesce:
            return True
        if not self._flush_resends(peer):
            return False     # NACK retransmits outrank queued new traffic
        keys = list(peer.coalesce) if ring == "all" else [ring]
        ok = True
        for key in keys:
            q = peer.coalesce.get(key)
            if q is None or not q.subs:
                peer.coalesce.pop(key, None)
                continue
            subs = q.subs
            mb0 = peer.rings[key if key is not None else 0].mailbox
            cap = mb0.slot_size
            agg_k = getattr(mb0, "agg_k", 0)
            max_subs = min(self._agg_max_subs, agg_k) if agg_k \
                else self._agg_max_subs
            posted = 0
            while posted < len(subs):
                lane = self._pick_lane(peer, key)
                if lane is None:
                    self._bp(peer)
                    ok = False
                    break
                take = self._split_budget(subs[posted:], cap, max_subs)
                self._post_agg(peer, lane, subs[posted:posted + take])
                posted += take
            if posted >= len(subs):
                peer.coalesce.pop(key, None)
            elif posted:
                nq = _CoalesceQ()          # keep the unposted tail queued
                for s in subs[posted:]:
                    nq.add(s)
                peer.coalesce[key] = nq
        return ok

    def flush_coalesced(self, peer_name: str | None = None,
                        ring: int | None = "all") -> bool:
        """Explicit coalescing-queue flush (all peers by default)."""
        if peer_name is not None:
            return self._flush_coalesce_peer(self.peers[peer_name], ring)
        ok = True
        for p in self.peers.values():
            ok = self._flush_coalesce_peer(p, ring) and ok
        return ok

    def _age_flush(self) -> None:
        """Flush any queue whose oldest record has waited past the age
        bound — the poll-side half of the adaptive policy."""
        now = time.monotonic()
        for p in self.peers.values():
            if not p.coalesce:
                continue
            for key in list(p.coalesce):
                q = p.coalesce.get(key)
                if (q is not None and q.subs
                        and now - q.subs[0].enq_at >= self._agg_max_age):
                    self._flush_coalesce_peer(p, key)

    def send(self, peer_name: str, msg, *, ring: int | None = None,
             on_complete=None, future=None) -> bool:
        """Post one ifunc message to a peer.  Returns False (and counts a
        backpressure event) when every eligible ring is out of credits.

        The frame is staged into the engine's slab cell for the chosen ring
        slot; if the peer is known to have this handle's code digest cached,
        the code section is elided on the fly (SLIM framing).  A corr_id
        already sealed into the message's header rides along — including
        across the on-the-fly SLIM repack."""
        peer = self.peers[peer_name]
        self._check_ring_kw(peer, ring)
        if not self._flush_resends(peer):
            self._bp(peer)
            return False
        if not self._flush_coalesce_peer(peer):
            # queued coalesced records precede this frame in program order:
            # they must post first or per-peer FIFO breaks
            self._bp(peer)
            return False
        lane = self._pick_lane(peer, ring)
        if lane is None:
            self._bp(peer)
            return False
        frame = msg.frame if hasattr(msg, "frame") else msg
        handle = getattr(msg, "handle", None)
        if handle is None:                       # raw frame: no slim protocol
            self._slab_post(peer, lane, frame, None, on_complete, future)
            return True
        lib = handle.lib
        corr_id = getattr(msg, "corr_id", 0)   # mirrored from the header at
        #                          msg-create time: no hot-path header parse
        cont = getattr(msg, "cont", None)   # mirrored at msg-create time
        if cont is not None and peer.fabric.kind == "device":
            raise TransportError(
                "continuation frames are host-tier only (the device sweep "
                "has no forwarding hook)")
        already_slim = bool(getattr(msg, "slim", False))
        want_slim = self._slim_ok(peer, lib)
        rec = _TxRec(lib.name, lib.code_digest, handle,
                     already_slim or want_slim, corr_id=corr_id)
        if rec.slim and peer.fabric.kind != "device":
            self._check_full_fits(lane, lib, len(msg.payload_view),
                                  0 if cont is None else len(cont))
        if want_slim and not already_slim:
            # elide the code section while staging — the slab cell is the
            # only buffer the SLIM frame ever occupies; the continuation
            # descriptor rides along untouched
            slab = self.engine.slab_slot(lane.channel, lane.tail)
            n = F.pack_frame_into(slab, lib.name, b"", msg.payload_view,
                                  lib.kind, digest=lib.code_digest, slim=True,
                                  corr_id=corr_id, cont=cont)
            self._post_view(peer, lane, slab[:n], rec, on_complete, future)
        else:
            self._slab_post(peer, lane, frame, rec, on_complete, future)
        return True

    def send_ifunc(self, peer_name: str, handle, source_args,
                   source_args_size: int | None = None, *,
                   ring: int | None = None, on_complete=None,
                   corr_id: int = 0, future=None,
                   cont: bytes | None = None) -> bool:
        """Fully zero-copy send: skips IfuncMsg materialization — the
        payload codec writes directly into the peer's slab cell and the
        header is sealed around it in place.  SLIM framing is applied
        automatically once the peer's cache is known-warm.  ``corr_id``
        nonzero requests a result-return reply (the Future path);
        ``cont`` appends a packed continuation descriptor (the flow
        layer's peer-to-peer forwarding path — host fabrics only)."""
        peer = self.peers[peer_name]
        self._check_ring_kw(peer, ring)
        if cont is not None and peer.fabric.kind == "device":
            raise TransportError(
                "continuation frames are host-tier only (the device sweep "
                "has no forwarding hook)")
        if (self._streaming and cont is None and on_complete is None
                and peer.fabric.kind != "device" and not peer.stripe):
            if source_args_size is None:
                try:
                    source_args_size = len(source_args)
                except TypeError:
                    source_args_size = 0
            if int(handle.lib.payload_get_max_size(
                    source_args, source_args_size)) > self._stream_thr:
                # oversized payload: the slot-bounded singleton is the
                # 64KiB cliff — materialize once and stream it instead
                payload = self._materialize_payload(handle.lib, source_args,
                                                    source_args_size)
                return self.send_stream(peer_name, handle, payload,
                                        ring=ring, corr_id=corr_id,
                                        future=future)
        if (self._coalesce and on_complete is None
                and self._agg_eligible(peer)
                and self._slim_ok(peer, handle.lib)):
            # cache-warm send with coalescing on: queue for aggregate
            # packing instead of claiming a ring slot per message (device
            # lanes participate when their mailboxes are agg-bound)
            return self._enqueue_sub(peer, handle, source_args,
                                     source_args_size, ring, corr_id,
                                     future, cont)
        if not self._flush_resends(peer):
            self._bp(peer)
            return False
        if not self._flush_coalesce_peer(peer):
            self._bp(peer)                    # FIFO: queued records go first
            return False
        lane = self._pick_lane(peer, ring)
        if lane is None:
            self._bp(peer)
            return False
        lib = handle.lib
        if source_args_size is None:
            try:
                source_args_size = len(source_args)
            except TypeError:
                source_args_size = 0
        max_size = int(lib.payload_get_max_size(source_args, source_args_size))
        cont_len = 0 if cont is None else len(cont)
        slim = self._slim_ok(peer, lib)
        if slim and peer.fabric.kind != "device":
            self._check_full_fits(lane, lib, max_size, cont_len)
        code = b"" if slim else lib.code
        slab = self.engine.slab_slot(lane.channel, lane.tail)
        if (F.HEADER_LEN + len(code) + max_size + cont_len
                + F.TRAILER_LEN) > len(slab):
            raise TransportError(
                f"frame would exceed slot {lane.mailbox.slot_size}B")
        pv = F.frame_payload_view(slab, len(code), max_size)
        used = lib.payload_init(pv, max_size, source_args, source_args_size)
        used = max_size if used in (None, 0) else int(used)
        n = F.seal_frame(slab, lib.name, code, lib.kind, used,
                         digest=lib.code_digest, slim=slim, corr_id=corr_id,
                         cont=cont)
        self._post_view(peer, lane, slab[:n],
                        _TxRec(lib.name, lib.code_digest, handle, slim,
                               corr_id=corr_id),
                        on_complete, future)
        return True

    def broadcast(self, make_msg) -> int:
        """``make_msg(peer) -> msg`` for every peer; returns #accepted."""
        return sum(bool(self.send(p, make_msg(peer)))
                   for p, peer in self.peers.items())

    def flush(self) -> int:
        """Publish all in-flight puts (completes trailers -> frames become
        consumable at the targets).  Coalescing queues flush first — an
        explicit flush means 'everything handed to send is on the wire'."""
        for p in self.peers.values():
            self._flush_coalesce_peer(p)
        if self._active_streams:
            self._pump_streams()
        return self.engine.flush()

    # -- target side: fairness-aware poll loop ------------------------------

    def _lanes(self) -> list[tuple[Peer, RingState]]:
        return [(p, r) for p in self.peers.values() for r in p.rings]

    def _rebuild_full(self, lane: RingState, abs_slot: int, rec: _TxRec):
        """NACK fallback: the SLIM frame still sits in the source slab cell
        for its slot (the credit only just returned, nothing has overwritten
        it); hand it to ``ifunc_msg_to_full`` to restore the code section."""
        A = _api()

        view = self.engine.slab_slot(lane.channel, abs_slot)
        return A.ifunc_msg_to_full(A.IfuncMsg(rec.handle, view, slim=True))

    def _sweep_task(self, peer: Peer, lane: RingState,
                    max_slots: int = 1) -> list:
        """Sweep up to ``max_slots`` ready slots of a reply-enabled host
        lane: per slot, capture the request's corr_id before execution
        destroys the frame, capture the ifunc's output
        (``target_args["result"]``) — or the exception it raised — after,
        and post the encoded reply.  An ifunc exception consumes the slot
        (clear + head advance) instead of wedging the ring; the error
        travels back as a FLAG_ERR reply.  A fire-and-forget frame
        (corr_id == 0) has no reply to carry the error, so after consuming
        the slot the exception re-raises to the poll caller — same
        visibility as a plain dispatcher; mid-batch, the raise is
        *deferred* until the statuses of the slots already swept in this
        batch have been processed (``poll`` re-raises it after this
        lane's completion), so a delivered aggregate ahead of a poisoned
        slot still confirms digests and resolves its futures.  Aggregate
        containers pass through untouched here (header corr is 0); their
        per-sub-record replies coalesce in :meth:`_complete_agg`."""
        Status = _api().Status

        mb = lane.mailbox
        out: list = []
        for _ in range(max_slots):
            buf = mb.slot_view(mb.head)
            hdr = mb.peek()                  # fabric-contract header peek
            corr = 0 if hdr is None else hdr.corr_id
            name = "" if hdr is None else hdr.name
            kind = F.CodeKind.PYBC if hdr is None else hdr.code_kind
            targs = peer.target_args
            if isinstance(targs, dict):
                targs.pop("result", None)
            err = None
            try:
                sts = mb.sweep(peer.target_ctx, targs, budget=1)
            except Exception as e:           # raised *inside* the ifunc
                err = e
                F.scrub_slot(buf)
                mb.streams.pop(mb.slot_coords(mb.head), None)   # a raising
                #              exec-on-arrival stream dies with its slot
                mb.head += 1                 # consume the poisoned slot
                mb.consumed += 1
                peer.stats["errors"] += 1
                if not corr:
                    if not out:
                        raise                # no future to carry the error
                    self._sweep_raise = e    # don't discard what the batch
                    break                    # already swept: raise after
                sts = [Status.OK]            # delivered — it just raised
            if corr and sts and sts[0] in (Status.OK, Status.REJECTED):
                if err is not None:
                    value, is_err = err, True
                elif sts[0] == Status.REJECTED:
                    value, is_err = TransportError(
                        str(peer.target_ctx.stats.get(
                            "last_reject", "frame rejected"))), True
                else:
                    value = (targs.get("result")
                             if isinstance(targs, dict) else None)
                    is_err = False
                self._post_reply(peer, name, kind, corr, value, is_err)
            out.extend(sts)
            if not sts or sts[-1] not in (Status.OK, Status.REJECTED,
                                          Status.NACK_UNCACHED):
                break                        # empty / in-progress: stop here
        return out

    def _complete_agg(self, peer: Peer, lane: RingState, rec: _TxRec,
                      coords) -> int:
        """Source-side completion of one delivered aggregate: walk the
        per-sub-record outcomes the target's sweep left in
        ``Mailbox.last_agg`` under ``coords`` — confirm cached digests,
        queue FULL-singleton retransmits for digest misses (ONLY the
        missed records; executed siblings are never replayed), and
        coalesce corr-carrying results into one reply frame (device
        lanes, which have no reply ring, route each result straight to
        the reply router instead).  Returns the number of consumed (OK or
        rejected) sub-records, i.e. this container's contribution to the
        poll budget."""
        A = _api()
        Status = A.Status
        o = self.obs
        if o.enabled:
            o.rtt_hist.observe((time.monotonic() - rec.sent_at) * 1e6)
            if rec.span is not None:
                o.tracer.end(rec.span, subs=len(rec.subs or ()))
                rec.span = None
        results = lane.mailbox.last_agg.pop(coords, None)
        if results is not None and len(results) != len(rec.subs):
            # a harvest that does not match the container we sent (an
            # external sweeper raced us, or the bounded stash evicted):
            # trusting per-index outcomes would misattribute NACKs —
            # treat as delivered-without-detail instead
            peer.stats["agg_harvest_lost"] += 1
            results = None
        cached_add = peer.cached.add
        subs = rec.subs
        ok_marker = A._AGG_PLAIN_OK
        if (results is not None and len(results) == len(subs)
                and all(r is ok_marker for r in results)):
            # the dominant outcome: every record executed clean,
            # fire-and-forget — the target handed back the shared OK
            # marker for all of them, so skip the per-record status
            # ladder (corr-carrying and device records always carry real
            # result objects and take the full walk below)
            for sub in subs:
                cached_add(sub.digest)
            peer.stats["delivered"] += len(subs)
            reply_subs = [(sub, None, False) for sub in subs if sub.corr_id]
            if reply_subs:
                self._post_agg_reply(peer, reply_subs)
            return len(subs)
        consumed = n_ok = n_rej = n_nack = n_err = 0
        reply_subs = []
        for i, sub in enumerate(rec.subs):
            res = (results[i] if results is not None and i < len(results)
                   else None)
            st = Status.OK if res is None else res.status
            if st == Status.NACK_UNCACHED:
                n_nack += 1
                if o.enabled:
                    o.recorder.add("nack", peer.name,
                                   f"agg sub {sub.name} corr={sub.corr_id}")
                peer.cached.discard(sub.digest)
                if sub.handle is not None:
                    lib = sub.handle.lib
                    frame = F.pack_frame(lib.name, lib.code, sub.payload,
                                         lib.kind, digest=lib.code_digest,
                                         corr_id=sub.corr_id, cont=sub.cont)
                    peer.resend.append(A.IfuncMsg(sub.handle, frame,
                                                  slim=False,
                                                  corr_id=sub.corr_id,
                                                  cont=sub.cont))
                else:
                    peer.stats["nack_lost"] += 1
                continue
            consumed += 1
            if st == Status.REJECTED:
                n_rej += 1
                if sub.corr_id:
                    err = (res.error if res is not None
                           and res.error is not None
                           else TransportError("sub-record rejected"))
                    reply_subs.append((sub, err, True))
                continue
            n_ok += 1
            cached_add(sub.digest)
            if sub.corr_id:
                if res is not None and res.error is not None:
                    n_err += 1
                    reply_subs.append((sub, res.error, True))
                else:
                    reply_subs.append(
                        (sub, res.value if res is not None else None, False))
        s = peer.stats                       # one batched stats update
        s["delivered"] += n_ok
        if n_rej:
            s["rejected"] += n_rej
        if n_err:
            s["errors"] += n_err
        if n_nack:
            s["nacks"] += n_nack
            self.stats["nacks"] += n_nack
        if reply_subs:
            if peer.fabric.kind == "device":
                # no reply ring on a mesh lane: the sweep's harvested
                # values ARE the results — route them directly, decoded
                for sub, value, is_err in reply_subs:
                    self._route_reply(sub.corr_id, peer.name, value,
                                      is_err, decoded=True)
                s["replies"] += len(reply_subs)
                self.stats["replies"] += len(reply_subs)
            else:
                self._post_agg_reply(peer, reply_subs)
        return consumed

    def _post_agg_reply(self, peer: Peer, reply_subs: list[tuple]) -> None:
        """Coalesce the results of one aggregate's corr-carrying records
        into ONE ``FLAG_AGG|FLAG_REPLY`` frame on the peer's reply ring —
        the response direction amortizes exactly like the request one.
        Falls back to singleton replies when there is only one result (or
        the encoded batch outgrows a reply slot)."""
        if peer.reply_channel is None or self.reply_codec is None:
            self.stats["reply_dropped"] += len(reply_subs)
            return
        codec = self.reply_codec
        wire = []
        for sub, value, is_err in reply_subs:
            try:
                payload = (codec.encode_error(value) if is_err
                           else codec.encode(value))
            except Exception as e:           # unencodable result: the error
                payload, is_err = codec.encode_error(e), True   # IS the reply
            wire.append(F.AggSub(sub.name, sub.kind, F.NO_DIGEST,
                                 sub.corr_id, payload, err=is_err))
        if (len(wire) > 1
                and F.agg_frame_len(wire) <= peer.reply_mailbox.slot_size):
            if peer.reply_credits <= 0:
                self._drain_replies(peer)
            slab = self.engine.slab_slot(peer.reply_channel, peer.reply_tail)
            n = F.seal_agg_frame(slab, wire, reply=True)
            self.engine.post(peer.reply_channel, slab[:n], peer.reply_tail,
                             peer=peer.name)
            peer.reply_tail += 1
            peer.stats["replies"] += len(wire)
            peer.stats["agg_replies"] += 1
            self.stats["replies"] += len(wire)
            return
        for sub, value, is_err in reply_subs:
            self._post_reply(peer, sub.name, sub.kind, sub.corr_id, value,
                             is_err)

    def _post_reply(self, peer: Peer, name: str, kind, corr: int, value,
                    is_err: bool) -> None:
        """Pack a result into a FLAG_REPLY frame and post it target->source.
        The source can always drain its own inbox, so a full reply ring is
        drained inline rather than dropping the result."""
        if peer.reply_channel is None or self.reply_codec is None:
            self.stats["reply_dropped"] += 1
            return
        if peer.reply_credits <= 0:
            self._drain_replies(peer)
        codec = self.reply_codec
        try:
            payload = (codec.encode_error(value) if is_err
                       else codec.encode(value))
        except Exception as e:               # unencodable result: the error
            payload, is_err = codec.encode_error(e), True   # IS the reply
        slab = self.engine.slab_slot(peer.reply_channel, peer.reply_tail)
        try:
            n = F.pack_reply_into(slab, name, payload, kind, corr, err=is_err)
        except F.FrameError as e:            # oversized value: error reply
            n = F.pack_reply_into(slab, name, codec.encode_error(e), kind,
                                  corr, err=True)
        self.engine.post(peer.reply_channel, slab[:n], peer.reply_tail,
                         peer=peer.name)
        peer.reply_tail += 1
        peer.stats["replies"] += 1
        self.stats["replies"] += 1

    def _route_reply(self, corr: int, name: str, value, is_err: bool,
                     decoded: bool) -> None:
        if self.reply_router is None:
            self.stats["reply_dropped"] += 1
            return
        self.reply_router(corr, name, value, is_err, decoded)

    def _drain_replies(self, peer: Peer, budget: int | None = None) -> int:
        """Source side of the reply path: flush the target's pending reply
        puts, then consume FLAG_REPLY frames from the peer's reply ring and
        hand them to the router.  Corrupt reply slots are cleared and
        counted, never wedged."""
        if peer.reply_mailbox is None:
            return 0
        self.engine.flush(peer.reply_channel)
        mb = peer.reply_mailbox
        n = 0
        while budget is None or n < budget:
            buf = mb.slot_view(mb.head)
            try:
                hdr = F.peek_header(buf)
            except F.FrameError:
                F.scrub_slot(buf)
                mb.head += 1
                mb.consumed += 1
                peer.stats["reply_rejects"] += 1
                continue
            if hdr is None or not F.trailer_arrived(buf, hdr):
                break
            if hdr.is_agg:
                # coalesced reply: one container, many corr_ids — one
                # vectorized table parse, one demux comprehension
                try:
                    routed = F.parse_agg(
                        F.frame_sections(buf, hdr)[1]).reply_tuples()
                except F.FrameError:
                    F.scrub_slot(buf)
                    mb.head += 1
                    mb.consumed += 1
                    peer.stats["reply_rejects"] = (
                        peer.stats.get("reply_rejects", 0) + 1)
                    continue
                F.clear_frame(buf, hdr)
                mb.head += 1
                mb.consumed += 1
                for corr, name, payload, is_err in routed:
                    if peer.fence and F.corr_gen(corr) < peer.fence:
                        peer.stats["fenced_orphans"] += 1
                        continue     # stale-generation record in a fresh
                        #              container: fence per record
                    self._route_reply(corr, name, payload, is_err,
                                      decoded=False)
                n += len(routed)
                continue
            payload = bytes(F.frame_sections(buf, hdr)[1])
            corr, name, is_err = hdr.corr_id, hdr.name, hdr.is_err
            F.clear_frame(buf, hdr)
            mb.head += 1
            mb.consumed += 1
            if peer.fence and F.corr_gen(corr) < peer.fence:
                # a reply stamped under an earlier fleet generation: this
                # peer died and was re-admitted since the request was
                # allocated, so whatever future the corr named was already
                # resolved (TransportError) by fail_inflight — executing the
                # route would resurrect it.  Count + drop.
                peer.stats["fenced_orphans"] += 1
                if self.obs.enabled:
                    self.obs.recorder.add(
                        "fenced_orphan", peer.name,
                        f"corr={corr} gen={F.corr_gen(corr)} "
                        f"fence={peer.fence}")
                n += 1
                continue
            self._route_reply(corr, name, payload, is_err, decoded=False)
            n += 1
        return n

    def poll_replies(self) -> int:
        """Drain every peer's reply ring; returns replies routed."""
        return sum(self._drain_replies(p) for p in self.peers.values())

    def poll(self, budget: int | None = None) -> int:
        """Drain up to ``budget`` messages total across all peers' rings,
        deficit-round-robin.  A *budgeted* poll visits every lane once per
        round, consuming at most one message per lane per round (so no
        ring monopolizes the poller), starting one lane past last round's
        first server.  An *unbudgeted* poll (the drain path) sweeps a
        whole ring's worth of ready slots per lane visit instead — one
        batched pass per lane, not one poll-loop round per message.  A
        device-mesh lane always sweeps whole-ring (its sweep is a single
        compiled pass); an aggregate container likewise yields all its
        sub-records at once — both can overshoot ``budget`` by one sweep.

        OK deliveries confirm the target's code cache for the frame's
        digest (enabling SLIM framing); NACK_UNCACHED consumes the slot,
        un-confirms the digest, and queues a FULL retransmit — for an
        aggregate, per sub-record.  Replies (result-return frames, device
        sweep results with corr-ids) are routed to the reply_router as a
        side effect; they do not count against ``budget``."""
        Status = _api().Status

        for cb in tuple(self.pollers):
            # side-band pollers (ElasticController heartbeat pump/sweep)
            # run BEFORE the lane snapshot: one may retire a dead peer,
            # and the data sweep below must not visit its rings
            cb()
        if self._coalesce:
            self._age_flush()            # adaptive bound: no record waits
            #                              longer than agg_max_age queued
        lanes = self._lanes()
        if not lanes:
            return 0
        done = 0
        self.stats["poll_rounds"] += 1
        take = 1 if budget is not None else None    # None -> whole ring
        progressed = True
        while progressed and (budget is None or done < budget):
            progressed = False
            if self._active_streams and self._pump_streams():
                progressed = True        # chunks posted: windows the sweeps
                #                          below just opened refill in-poll
            start = self._rr % len(lanes)
            for k in range(len(lanes)):
                peer, lane = lanes[(start + k) % len(lanes)]
                if budget is not None and done >= budget:
                    break
                if (self.faults is not None
                        and self.faults.is_down(
                            peer.name,
                            delivered=peer.stats["delivered"])):
                    continue     # injected death: the peer's progress side
                    #              is gone — posted frames sit undelivered
                    #              until the heartbeat deadline recovers them
                if peer.stripe and lane is not peer.rings[
                        peer.stripe_rx % len(peer.rings)]:
                    continue         # striped peer: consume in the same
                    #                  strict rotation the posts followed,
                    #                  one frame per visit — per-peer FIFO
                take_eff = 1 if peer.stripe else take
                track = peer.fabric.kind != "device"
                slot = lane.mailbox.head
                if track and peer.reply_channel is not None:
                    sts = self._sweep_task(
                        peer, lane,
                        take_eff if take_eff is not None
                        else lane.mailbox.n_slots)
                    coords = res_new = None
                elif track:
                    sts = lane.mailbox.sweep(peer.target_ctx,
                                             peer.target_args,
                                             budget=take_eff)
                    coords = res_new = None
                else:
                    res_before = len(getattr(lane.mailbox, "results", ()))
                    sts = lane.mailbox.sweep(peer.target_ctx,
                                             peer.target_args, budget=1)
                    coords = getattr(lane.mailbox, "last_coords", None)
                    res_new = list(getattr(lane.mailbox, "results",
                                           ())[res_before:])
                ri = 0                       # cursor over res_new
                for i, st in enumerate(sts):
                    rec = None
                    coord = (coords[i] if coords is not None
                             and i < len(coords) else None)
                    if st in (Status.OK, Status.REJECTED,
                              Status.NACK_UNCACHED):
                        if track:
                            rec = lane.inflight.pop(slot, None)
                        elif coord is not None:
                            rec = lane.agg_by_coords.pop(coord, None)
                        slot += 1
                    if st == Status.OK:
                        progressed = True
                        if not track:
                            # one results entry lands per device container
                            # (aggregate or singleton): consume the cursor
                            # BEFORE branching so later statuses in this
                            # sweep stay aligned
                            val = res_new[ri] if ri < len(res_new) else None
                            ri += 1
                        if rec is not None and rec.subs is not None:
                            # aggregate container: per-sub-record
                            # completion (cache confirms, individual NACK
                            # rebuilds, one coalesced reply)
                            done += self._complete_agg(
                                peer, lane, rec,
                                coord if not track
                                else lane.mailbox.slot_coords(slot - 1))
                            continue
                        peer.stats["delivered"] += 1
                        done += 1
                        if rec is not None:
                            peer.cached.add(rec.digest)
                            if rec.stream is not None:
                                rec.stream.dead = True   # complete: pump off
                            o = self.obs
                            if o.enabled:
                                o.rtt_hist.observe(
                                    (time.monotonic() - rec.sent_at) * 1e6)
                                if rec.span is not None:
                                    o.tracer.end(rec.span, status="ok")
                                    rec.span = None
                        if not track:
                            ent = (lane.corr_by_coords.pop(coord, None)
                                   if coord is not None else None)
                            if ent:          # device reply: the result IS it
                                self._route_reply(ent[0], peer.name, val,
                                                  False, decoded=True)
                    elif st == Status.REJECTED:
                        peer.stats["rejected"] += 1
                        done += 1
                        progressed = True
                        if rec is not None:
                            o = self.obs
                            if o.enabled:
                                o.recorder.add(
                                    "reject", peer.name,
                                    f"{rec.name} corr={rec.corr_id}")
                                if rec.span is not None:
                                    o.tracer.end(rec.span,
                                                 status="rejected")
                                    rec.span = None
                        if rec is not None and rec.stream is not None:
                            # corrupt stream: ONLY this stream dies — stop
                            # its pump; the scrubbed slot flows on
                            rec.stream.dead = True
                        if rec is not None and rec.subs is not None:
                            # whole container rejected (corrupt aggregate
                            # signal): every corr-carrying record resolves
                            # with the transport error — none executed
                            for sub in rec.subs:
                                if sub.corr_id:
                                    self._route_reply(
                                        sub.corr_id, peer.name,
                                        TransportError(
                                            "aggregate container rejected"),
                                        True, decoded=True)
                        if not track and coord is not None:
                            ent = lane.corr_by_coords.pop(coord, None)
                            corr = ent[0] if ent else 0
                            if corr:
                                self._route_reply(
                                    corr, peer.name,
                                    "frame rejected on device sweep",
                                    True, decoded=True)
                    elif st == Status.NACK_UNCACHED:
                        peer.stats["nacks"] += 1
                        self.stats["nacks"] += 1
                        progressed = True
                        if rec is not None:
                            o = self.obs
                            if o.enabled:
                                o.recorder.add(
                                    "nack", peer.name,
                                    f"{rec.name} corr={rec.corr_id} "
                                    f"slim miss")
                                if rec.span is not None:
                                    o.tracer.end(rec.span, status="nack")
                                    rec.span = None
                        if rec is not None and rec.stream is not None:
                            # SLIM stream missed the cache at its
                            # descriptor: park the pump and queue a FULL
                            # re-open from chunk 0 (nothing executed)
                            rec.stream.dead = True
                            peer.cached.discard(rec.digest)
                            peer.resend.append(_StreamResend(rec.stream))
                        elif rec is not None and rec.handle is not None:
                            peer.cached.discard(rec.digest)
                            peer.resend.append(
                                self._rebuild_full(lane, slot - 1, rec))
                        else:
                            # a SLIM frame we have no record/handle for (raw
                            # send): nothing to rebuild — surface the loss
                            peer.stats["nack_lost"] += 1
                    elif st == Status.IN_PROGRESS:
                        peer.stats["inflight_polls"] += 1
                if peer.stripe:
                    # rotation advances one step per consumed slot, so the
                    # next visit reads the ring the next post landed in
                    peer.stripe_rx += sum(
                        1 for st in sts
                        if st in (Status.OK, Status.REJECTED,
                                  Status.NACK_UNCACHED))
                err = (self._sweep_raise
                       or getattr(lane.mailbox, "pending_raise", None))
                if err is not None:
                    # a corr-less poisoned slot mid-batch (from either the
                    # reply-lane _sweep_task or a plain Mailbox.sweep):
                    # its lane's completed statuses (digest confirms,
                    # aggregate completions, replies) are processed above
                    # — NOW the exception gets its historical visibility
                    self._sweep_raise = None
                    lane.mailbox.pending_raise = None
                    raise err
            self._rr += 1
        self.poll_replies()
        self.stats["polled"] += done
        return done

    def _pending_inflight(self) -> int:
        """Tracked frames still awaiting their target's sweep: host-lane
        inflight records (past-consumed records are pruned as a side
        effect) plus device-lane corr-ids awaiting a sweep result, plus
        coalesced records still queued for an aggregate flush."""
        n = 0
        for peer in self.peers.values():
            for lane in peer.rings:
                low = lane.mailbox.consumed
                for s in [s for s in lane.inflight if s < low]:
                    del lane.inflight[s]
                n += (len(lane.inflight) + len(lane.corr_by_coords)
                      + len(lane.agg_by_coords))
            n += len(peer.resend)
            n += sum(len(q.subs) for q in peer.coalesce.values())
        return n

    def fail_inflight(self, reason: str = "liveness deadline exceeded",
                      min_age: float = 0.0,
                      peers: set | None = None) -> int:
        """Give up on tracked in-flight frames at least ``min_age`` seconds
        old: corr-carrying records resolve their futures with a
        TransportError through the reply router (instead of hanging
        forever on a wedged peer); the records and that peer's queued
        retransmits are dropped.  ``min_age`` is what makes this a *per
        frame* liveness floor — a healthy peer actively consuming its
        backlog only has young records, and keeps them.  ``peers`` scopes
        the pass to named peers (the elastic failure path: ONE peer died;
        everyone else's in-flight work is healthy and must not be touched).
        Returns futures failed."""
        now = time.monotonic()
        failed = 0
        targets = (list(self.peers.values()) if peers is None
                   else [p for n, p in self.peers.items() if n in peers])
        for peer in targets:
            timed_out = 0
            for lane in peer.rings:
                low = lane.mailbox.consumed
                for slot in sorted(lane.inflight):
                    rec = lane.inflight[slot]
                    if slot >= low and now - rec.sent_at < min_age:
                        continue         # young: the peer may still be alive
                    del lane.inflight[slot]
                    o = self.obs
                    if o.enabled and rec.span is not None:
                        o.tracer.end(rec.span, status="failed")
                        rec.span = None
                    if rec.stream is not None:
                        rec.stream.dead = True   # half-arrived stream: the
                        #          pump must never touch the slot again
                        if rec.stream in self._active_streams:
                            self._active_streams.remove(rec.stream)
                    if slot < low:
                        continue
                    if o.enabled:
                        o.recorder.add(
                            "fail_inflight", peer.name,
                            f"{rec.name} corr={rec.corr_id} "
                            f"age={now - rec.sent_at:.3f}s")
                    if rec.subs is not None:
                        for sub in rec.subs:   # aggregate: fail per record
                            if sub.corr_id:
                                self._route_reply(
                                    sub.corr_id, peer.name,
                                    TransportError(
                                        f"{sub.name} (coalesced) to "
                                        f"{peer.name!r}: {reason}"),
                                    True, decoded=True)
                                timed_out += 1
                        continue
                    if not rec.corr_id:
                        continue
                    self._route_reply(
                        rec.corr_id, peer.name,
                        TransportError(
                            f"{rec.name} to {peer.name!r}: {reason} "
                            f"(in flight {now - rec.sent_at:.3f}s)"),
                        True, decoded=True)
                    timed_out += 1
                for coords, (corr, sent_at) in list(
                        lane.corr_by_coords.items()):
                    if now - sent_at < min_age:
                        continue
                    del lane.corr_by_coords[coords]
                    self._route_reply(
                        corr, peer.name,
                        TransportError(
                            f"device lane {peer.name!r}: {reason}"),
                        True, decoded=True)
                    timed_out += 1
                for coords, rec in list(lane.agg_by_coords.items()):
                    if now - rec.sent_at < min_age:
                        continue         # device aggregate: fail per record
                    del lane.agg_by_coords[coords]
                    for sub in rec.subs or ():
                        if sub.corr_id:
                            self._route_reply(
                                sub.corr_id, peer.name,
                                TransportError(
                                    f"{sub.name} (device agg) to "
                                    f"{peer.name!r}: {reason}"),
                                True, decoded=True)
                            timed_out += 1
            if timed_out:
                while peer.resend:       # retransmits to a dead peer: drop
                    msg = peer.resend.popleft()
                    corr = getattr(msg, "corr_id", 0)
                    if corr:
                        self._route_reply(
                            corr, peer.name,
                            TransportError(
                                f"queued retransmit to {peer.name!r}: "
                                f"{reason}"),
                            True, decoded=True)
                        timed_out += 1
                for key in list(peer.coalesce):  # queued coalesced records
                    q = peer.coalesce.pop(key)   # to a dead peer: drop too
                    for sub in q.subs:
                        if sub.corr_id:
                            self._route_reply(
                                sub.corr_id, peer.name,
                                TransportError(
                                    f"queued coalesced {sub.name} to "
                                    f"{peer.name!r}: {reason}"),
                                True, decoded=True)
                            timed_out += 1
                peer.stats["timed_out"] += timed_out
                failed += timed_out
        self.stats["timed_out"] += failed
        if failed:
            o = self.obs
            if o.enabled:
                o.recorder.add("fail_inflight", "",
                               f"{failed} futures failed: {reason}")
                if o.dump_on_fail:
                    o.dump(f"fail_inflight: {reason}")
        return failed

    def drain(self, max_rounds: int = 64, deadline: float | None = None) -> int:
        """flush + poll until quiescent: no outstanding puts, no consumable
        frames, no queued retransmits.  Returns total messages
        delivered/rejected (NACK-retransmitted frames count once, when the
        FULL retry lands).

        ``deadline`` (seconds) is the liveness floor: the drain keeps
        cranking while tracked frames are still in flight (``max_rounds``
        does not apply — the bound is wall time), and once the deadline
        passes it *fails*, via :meth:`fail_inflight`, the futures of
        frames that were in flight for at least the whole deadline —
        frames a peer actively consuming its backlog would have drained.
        Without a deadline, behavior is the historical round-bounded
        quiescence check."""
        t0 = time.monotonic()
        total = 0
        rounds = 0
        while True:
            rounds += 1
            for p in self.peers.values():
                self._flush_resends(p)
                self._flush_coalesce_peer(p)   # drain = explicit flush
            self.engine.progress()
            n = self.poll()
            total += n
            idle = (n == 0 and self.engine.outstanding() == 0
                    and not self._active_streams
                    and not any(p.resend or any(
                        q.subs for q in p.coalesce.values())
                        for p in self.peers.values()))
            if deadline is None:
                if idle or rounds >= max_rounds:
                    break
            else:
                if idle and self._pending_inflight() == 0:
                    break
                if time.monotonic() - t0 >= deadline:
                    self.obs.record(
                        "drain_deadline", "",
                        f"{deadline:.3g}s exceeded, "
                        f"{self._pending_inflight()} frames inflight")
                    self.fail_inflight(
                        f"drain deadline ({deadline:.3g}s) exceeded",
                        min_age=deadline)
                    break
                if idle:
                    time.sleep(0)    # wedged-peer spin: be scheduler-polite
        return total

    # -- reporting ----------------------------------------------------------

    def per_peer_stats(self) -> dict[str, dict]:
        now = time.monotonic()
        return {name: dict(p.stats, credits=p.credits,
                           oldest_inflight_s=round(
                               p.oldest_inflight_age(now), 6))
                for name, p in self.peers.items()}

    def print_stats(self) -> None:
        for p in self.peers.values():
            print(" ", p.summary())


__all__ = ["DEFAULT_N_SLOTS", "DEFAULT_SLOT_SIZE", "Dispatcher", "Peer",
           "RingState"]
