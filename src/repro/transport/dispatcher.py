"""Multi-peer ifunc dispatcher: N peers x M rings, credit-based flow
control, per-peer backpressure, and a fairness-aware poll loop.

This replaces the single-slot ``poll_ring`` pattern: instead of one source
spinning on one ring, a :class:`Dispatcher` owns any number of
:class:`Peer` s — each a (fabric, channel(s), mailbox(s), target context)
bundle on *any* backend (RDMA host, device mesh, loopback/CSD) — and

* ``send`` consumes a credit (one free ring slot) or reports backpressure
  instead of silently overwriting unconsumed frames;
* credits return as the target's sweep advances its mailbox ``consumed``
  counter (the credit-return counter a real target writes back);
* ``poll`` drains mailboxes deficit-round-robin, starting one past the
  ring served first last time, so a chatty peer cannot starve the rest;
* all sends go through a shared :class:`ProgressEngine`, so batching,
  in-flight windows, and completions are uniform across fabrics.

The dispatcher also owns the *cached-invocation fast path* (paper §3.4):

* every frame is packed straight into the engine's per-peer slab cell for
  its ring slot (``pack_frame_into``/``seal_frame``) — the send path
  allocates no per-message buffers;
* a peer's first delivery of an ifunc ships a FULL frame; once the
  delivery is confirmed (the target's link cache provably holds the code
  digest) subsequent sends of the same handle flip to SLIM frames — header
  + payload, code elided;
* a SLIM frame that misses the target's cache (eviction, restart) comes
  back as ``NACK_UNCACHED``: the dispatcher rebuilds the FULL frame from
  the handle's library + the slab-resident payload and retransmits it
  transparently, ahead of any newer traffic to that peer;
* device-mesh lanes are always SLIM-eligible — the μVM program is bound at
  mailbox-open time, so code words never need depositing over the ICI.

And the *result-return path* (the task runtime's wire, see ``repro.tasks``):

* a request carrying a nonzero ``corr_id`` asks for the ifunc's output
  back; host peers get a *reply ring* — a source-owned mailbox the target
  writes ``FLAG_REPLY`` frames into — attached via ``attach_reply_ring``;
* the poll loop, when it executes a corr-carrying request at a host peer,
  captures the ifunc's ``target_args["result"]`` (or the exception it
  raised — the slot is consumed, not wedged) and posts the encoded value
  as a reply frame with the same corr_id; ``poll_replies`` drains reply
  rings and hands ``(corr_id, value)`` to the registered ``reply_router``;
* device-mesh lanes have no reverse ring: the sweep's READY results *are*
  the replies — the dispatcher correlates them to corr-ids by the
  (shard, slot) coordinates each send staged into and routes them through
  the same ``reply_router``;
* encoding is delegated to a pluggable ``reply_codec`` (the task layer's
  wire module) so the transport stays value-format-agnostic.

Plus the flow layer's *continuation frames* and the *liveness floor*:

* ``send``/``send_ifunc`` carry an optional packed continuation
  descriptor (frame v2.2 ``cont`` section, host fabrics only); the
  on-the-fly SLIM repack and the NACK FULL-rebuild both preserve it, so
  a retransmitted hop never loses its route;
* every tracked in-flight frame is timestamped: ``per_peer_stats()``
  surfaces the oldest age per peer, and ``drain(deadline=...)`` fails
  the futures of frames stuck at a wedged peer (``fail_inflight``)
  instead of letting them hang forever.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.core import frame as F
from repro.transport.fabric import Fabric, TransportError
from repro.transport.progress import ProgressEngine

DEFAULT_SLOT_SIZE = 64 << 10
DEFAULT_N_SLOTS = 8


@dataclass
class _TxRec:
    """Source-side record of one in-flight frame (for digest confirmation,
    NACK retransmission, reply correlation, and liveness tracking)."""

    name: str
    digest: bytes
    handle: object          # IfuncHandle (None for raw-frame sends)
    slim: bool
    corr_id: int = 0
    sent_at: float = field(default_factory=time.monotonic)


@dataclass
class RingState:
    """One (mailbox, channel) lane of a peer."""

    mailbox: object
    channel: object
    tail: int = 0            # source-side produce index
    inflight: dict = field(default_factory=dict)   # abs slot -> _TxRec
    corr_by_coords: dict = field(default_factory=dict)  # device lanes:
    #                                    (shard, slot) -> (corr_id, sent_at)
    #                                    awaiting a sweep result

    @property
    def credits(self) -> int:
        return self.mailbox.n_slots - (self.tail - self.mailbox.consumed)


@dataclass
class Peer:
    name: str
    fabric: Fabric
    target_ctx: object
    target_args: dict
    rings: list[RingState] = field(default_factory=list)
    cached: set = field(default_factory=set)       # digests confirmed cached
    resend: deque = field(default_factory=deque)   # FULL msgs queued post-NACK
    reply_mailbox: object = None   # source-owned ring the target replies into
    reply_channel: object = None   # target->source path into it
    reply_tail: int = 0            # target-side produce index for replies
    stats: dict = field(default_factory=lambda: {
        "sent": 0, "bytes": 0, "delivered": 0, "rejected": 0,
        "backpressure": 0, "inflight_polls": 0,
        "slim_sent": 0, "nacks": 0, "resent": 0,
        "replies": 0, "errors": 0})

    @property
    def credits(self) -> int:
        return sum(r.credits for r in self.rings)

    @property
    def reply_credits(self) -> int:
        if self.reply_mailbox is None:
            return 0
        return self.reply_mailbox.n_slots - (self.reply_tail
                                             - self.reply_mailbox.consumed)

    def oldest_inflight_age(self, now: float | None = None) -> float:
        """Age (seconds) of the oldest tracked frame still awaiting its
        target's sweep — the liveness floor signal.  0.0 when nothing is
        in flight.  Covers handle sends on host lanes and corr-carrying
        stages on device lanes (``corr_by_coords``), so a wedged mesh is
        as visible as a wedged host ring."""
        now = time.monotonic() if now is None else now
        oldest = None
        for r in self.rings:
            for slot, rec in r.inflight.items():
                if slot < r.mailbox.consumed:
                    continue            # consumed by an external sweeper
                if oldest is None or rec.sent_at < oldest:
                    oldest = rec.sent_at
            for _, sent_at in r.corr_by_coords.values():
                if oldest is None or sent_at < oldest:
                    oldest = sent_at
        return 0.0 if oldest is None else max(0.0, now - oldest)

    def summary(self) -> str:
        s = self.stats
        return (f"{self.name:<12s} fabric={self.fabric.kind:<9s} "
                f"sent={s['sent']:<4d} slim={s['slim_sent']:<4d} "
                f"delivered={s['delivered']:<4d} "
                f"rejected={s['rejected']:<3d} nacks={s['nacks']:<3d} "
                f"backpressure={s['backpressure']:<3d} "
                f"replies={s['replies']:<4d} "
                f"credits={self.credits}")


class Dispatcher:
    """One source fanning ifunc frames out to heterogeneous targets."""

    def __init__(self, src_ctx=None, engine: ProgressEngine | None = None):
        self.src_ctx = src_ctx
        self.engine = engine if engine is not None else ProgressEngine()
        self.peers: dict[str, Peer] = {}
        self._rr = 0             # fairness cursor over (peer, ring) lanes
        self.stats = {"sent": 0, "polled": 0, "poll_rounds": 0, "nacks": 0,
                      "replies": 0, "reply_dropped": 0}
        # task-runtime hooks (see repro.tasks): the router receives
        # (corr_id, name, value, is_err, decoded); the codec provides
        # encode(value)->bytes / encode_error(exc)->bytes for reply frames
        self.reply_router = None
        self.reply_codec = None

    # -- topology -----------------------------------------------------------

    def add_peer(self, name: str, fabric: Fabric, target_ctx, *,
                 n_slots: int = DEFAULT_N_SLOTS,
                 slot_size: int = DEFAULT_SLOT_SIZE,
                 rings: int = 1, target_args: dict | None = None,
                 **mailbox_kw) -> Peer:
        """``mailbox_kw`` passes backend-specific binds through to
        ``fabric.open_mailbox`` (e.g. ``prog=``/``externals=`` on the
        device-mesh fabric)."""
        if name in self.peers:
            raise TransportError(f"peer {name!r} already attached")
        peer = Peer(name, fabric, target_ctx,
                    target_args if target_args is not None else {})
        for _ in range(rings):
            mb = fabric.open_mailbox(target_ctx, n_slots, slot_size,
                                     **mailbox_kw)
            ch = fabric.connect(self.src_ctx, mb)
            peer.rings.append(RingState(mb, ch))
        self.peers[name] = peer
        return peer

    def attach_reply_ring(self, name: str, mailbox, channel) -> None:
        """Give a host peer a result-return path: ``mailbox`` is a
        source-owned ring (opened on the *source* context), ``channel`` the
        target->source path into it.  Corr-carrying requests executed at
        this peer post their outputs here as FLAG_REPLY frames; device-mesh
        peers need none (sweep results are correlated directly)."""
        peer = self.peers[name]
        if peer.fabric.kind == "device":
            raise TransportError(
                "device-mesh peers reply through the sweep, not a ring")
        peer.reply_mailbox = mailbox
        peer.reply_channel = channel
        peer.reply_tail = 0

    def remove_peer(self, name: str) -> None:
        peer = self.peers.pop(name, None)
        if peer is not None:
            for r in peer.rings:
                self.engine.release_slab(r.channel)
            if peer.reply_channel is not None:
                self.engine.release_slab(peer.reply_channel)

    # -- source side --------------------------------------------------------

    def _slim_ok(self, peer: Peer, lib) -> bool:
        """SLIM-eligible: device lanes link at mailbox-open time (code never
        travels); host lanes need a confirmed FULL delivery of this digest."""
        if peer.fabric.kind == "device":
            return True
        return lib.code_digest in peer.cached

    def _check_full_fits(self, lane: RingState, lib, payload_len: int,
                         cont_len: int = 0) -> None:
        """A SLIM frame must stay FULL-retransmittable: if the target evicts
        the digest, the NACK fallback rebuilds code + payload (+ any
        continuation descriptor) into this same ring — reject at send time
        rather than crash a later drain."""
        need = (F.HEADER_LEN + len(lib.code) + payload_len + cont_len
                + F.TRAILER_LEN)
        if need > lane.mailbox.slot_size:
            raise TransportError(
                f"SLIM frame's FULL fallback ({need}B) exceeds slot "
                f"{lane.mailbox.slot_size}B — NACK retransmit impossible")

    def _pick_lane(self, peer: Peer, ring: int | None) -> RingState | None:
        lanes = peer.rings if ring is None else [peer.rings[ring]]
        lane = max(lanes, key=lambda r: r.credits)
        return lane if lane.credits > 0 else None

    def _post_view(self, peer: Peer, lane: RingState, view, rec, on_complete,
                   future=None):
        self.engine.post(lane.channel, view, lane.tail, peer=peer.name,
                         on_complete=on_complete, future=future)
        if rec is not None and peer.fabric.kind != "device":
            lane.inflight[lane.tail] = rec
            if len(lane.inflight) > 2 * lane.mailbox.n_slots:
                # target sweeps outside our poll loop (e.g. WorkerAgent):
                # drop records for slots already consumed elsewhere
                low = lane.mailbox.consumed
                for s in [s for s in lane.inflight if s < low]:
                    del lane.inflight[s]
        if (rec is not None and rec.corr_id
                and peer.fabric.kind == "device"):
            # device replies come back as sweep results at the coordinates
            # this send stages into (the Mailbox.slot_coords contract)
            lane.corr_by_coords[lane.mailbox.slot_coords(lane.tail)] = (
                rec.corr_id, rec.sent_at)
        lane.tail += 1
        peer.stats["sent"] += 1
        peer.stats["bytes"] += len(view)
        if rec is not None and rec.slim:
            peer.stats["slim_sent"] += 1
        self.stats["sent"] += 1

    def _slab_post(self, peer: Peer, lane: RingState, frame, rec,
                   on_complete=None, future=None) -> None:
        """Stage a ready frame into the lane's slab cell and post it."""
        slab = self.engine.slab_slot(lane.channel, lane.tail)
        n = len(frame)
        if n > len(slab):
            raise TransportError(
                f"frame {n}B exceeds slot {lane.mailbox.slot_size}B")
        slab[:n] = frame
        self._post_view(peer, lane, slab[:n], rec, on_complete, future)

    def _flush_resends(self, peer: Peer) -> bool:
        """Post queued FULL retransmits (NACK fallback) ahead of any new
        traffic; False while the queue cannot drain.

        Retransmits are held until the peer's rings are quiescent (every
        in-flight frame resolved): an eviction NACKs *all* in-flight SLIM
        frames of the digest, but the NACKs surface one poll at a time —
        posting the first rebuild (or any newer frame) before the rest have
        reported would reorder execution at the target.  Waiting for
        quiescence makes the resend queue a faithful replay of ring order,
        so per-peer FIFO survives eviction storms."""
        if not peer.resend:
            return True
        if any(r.tail != r.mailbox.consumed for r in peer.rings):
            return False                       # storm not fully observed yet
        while peer.resend:
            lane = self._pick_lane(peer, None)
            if lane is None:
                return False
            msg = peer.resend.popleft()
            self._slab_post(peer, lane, msg.frame,
                            _TxRec(msg.handle.lib.name,
                                   msg.handle.lib.code_digest,
                                   msg.handle, slim=False,
                                   corr_id=getattr(msg, "corr_id", 0)))
            peer.stats["resent"] += 1
        return True

    def send(self, peer_name: str, msg, *, ring: int | None = None,
             on_complete=None, future=None) -> bool:
        """Post one ifunc message to a peer.  Returns False (and counts a
        backpressure event) when every eligible ring is out of credits.

        The frame is staged into the engine's slab cell for the chosen ring
        slot; if the peer is known to have this handle's code digest cached,
        the code section is elided on the fly (SLIM framing).  A corr_id
        already sealed into the message's header rides along — including
        across the on-the-fly SLIM repack."""
        peer = self.peers[peer_name]
        if not self._flush_resends(peer):
            peer.stats["backpressure"] += 1
            return False
        lane = self._pick_lane(peer, ring)
        if lane is None:
            peer.stats["backpressure"] += 1
            return False
        frame = msg.frame if hasattr(msg, "frame") else msg
        handle = getattr(msg, "handle", None)
        if handle is None:                       # raw frame: no slim protocol
            self._slab_post(peer, lane, frame, None, on_complete, future)
            return True
        lib = handle.lib
        corr_id = getattr(msg, "corr_id", 0)   # mirrored from the header at
        #                          msg-create time: no hot-path header parse
        cont = getattr(msg, "cont", None)   # mirrored at msg-create time
        if cont is not None and peer.fabric.kind == "device":
            raise TransportError(
                "continuation frames are host-tier only (the device sweep "
                "has no forwarding hook)")
        already_slim = bool(getattr(msg, "slim", False))
        want_slim = self._slim_ok(peer, lib)
        rec = _TxRec(lib.name, lib.code_digest, handle,
                     already_slim or want_slim, corr_id=corr_id)
        if rec.slim and peer.fabric.kind != "device":
            self._check_full_fits(lane, lib, len(msg.payload_view),
                                  0 if cont is None else len(cont))
        if want_slim and not already_slim:
            # elide the code section while staging — the slab cell is the
            # only buffer the SLIM frame ever occupies; the continuation
            # descriptor rides along untouched
            slab = self.engine.slab_slot(lane.channel, lane.tail)
            n = F.pack_frame_into(slab, lib.name, b"", msg.payload_view,
                                  lib.kind, digest=lib.code_digest, slim=True,
                                  corr_id=corr_id, cont=cont)
            self._post_view(peer, lane, slab[:n], rec, on_complete, future)
        else:
            self._slab_post(peer, lane, frame, rec, on_complete, future)
        return True

    def send_ifunc(self, peer_name: str, handle, source_args,
                   source_args_size: int | None = None, *,
                   ring: int | None = None, on_complete=None,
                   corr_id: int = 0, future=None,
                   cont: bytes | None = None) -> bool:
        """Fully zero-copy send: skips IfuncMsg materialization — the
        payload codec writes directly into the peer's slab cell and the
        header is sealed around it in place.  SLIM framing is applied
        automatically once the peer's cache is known-warm.  ``corr_id``
        nonzero requests a result-return reply (the Future path);
        ``cont`` appends a packed continuation descriptor (the flow
        layer's peer-to-peer forwarding path — host fabrics only)."""
        peer = self.peers[peer_name]
        if cont is not None and peer.fabric.kind == "device":
            raise TransportError(
                "continuation frames are host-tier only (the device sweep "
                "has no forwarding hook)")
        if not self._flush_resends(peer):
            peer.stats["backpressure"] += 1
            return False
        lane = self._pick_lane(peer, ring)
        if lane is None:
            peer.stats["backpressure"] += 1
            return False
        lib = handle.lib
        if source_args_size is None:
            try:
                source_args_size = len(source_args)
            except TypeError:
                source_args_size = 0
        max_size = int(lib.payload_get_max_size(source_args, source_args_size))
        cont_len = 0 if cont is None else len(cont)
        slim = self._slim_ok(peer, lib)
        if slim and peer.fabric.kind != "device":
            self._check_full_fits(lane, lib, max_size, cont_len)
        code = b"" if slim else lib.code
        slab = self.engine.slab_slot(lane.channel, lane.tail)
        if (F.HEADER_LEN + len(code) + max_size + cont_len
                + F.TRAILER_LEN) > len(slab):
            raise TransportError(
                f"frame would exceed slot {lane.mailbox.slot_size}B")
        pv = F.frame_payload_view(slab, len(code), max_size)
        used = lib.payload_init(pv, max_size, source_args, source_args_size)
        used = max_size if used in (None, 0) else int(used)
        n = F.seal_frame(slab, lib.name, code, lib.kind, used,
                         digest=lib.code_digest, slim=slim, corr_id=corr_id,
                         cont=cont)
        self._post_view(peer, lane, slab[:n],
                        _TxRec(lib.name, lib.code_digest, handle, slim,
                               corr_id=corr_id),
                        on_complete, future)
        return True

    def broadcast(self, make_msg) -> int:
        """``make_msg(peer) -> msg`` for every peer; returns #accepted."""
        return sum(bool(self.send(p, make_msg(peer)))
                   for p, peer in self.peers.items())

    def flush(self) -> int:
        """Publish all in-flight puts (completes trailers -> frames become
        consumable at the targets)."""
        return self.engine.flush()

    # -- target side: fairness-aware poll loop ------------------------------

    def _lanes(self) -> list[tuple[Peer, RingState]]:
        return [(p, r) for p in self.peers.values() for r in p.rings]

    def _rebuild_full(self, lane: RingState, abs_slot: int, rec: _TxRec):
        """NACK fallback: the SLIM frame still sits in the source slab cell
        for its slot (the credit only just returned, nothing has overwritten
        it); hand it to ``ifunc_msg_to_full`` to restore the code section."""
        from repro.core import api as A

        view = self.engine.slab_slot(lane.channel, abs_slot)
        return A.ifunc_msg_to_full(A.IfuncMsg(rec.handle, view, slim=True))

    def _sweep_task(self, peer: Peer, lane: RingState) -> list:
        """Sweep one slot of a reply-enabled host lane: capture the
        request's corr_id before execution destroys the frame, capture the
        ifunc's output (``target_args["result"]``) — or the exception it
        raised — after, and post the encoded reply.  An ifunc exception
        consumes the slot (clear + head advance) instead of wedging the
        ring; the error travels back as a FLAG_ERR reply.  A
        fire-and-forget frame (corr_id == 0) has no reply to carry the
        error, so after consuming the slot the exception re-raises to the
        poll caller — same visibility as a plain dispatcher."""
        from repro.core.api import Status

        mb = lane.mailbox
        buf = mb.slot_view(mb.head)
        hdr = mb.peek()                      # fabric-contract header peek
        corr = 0 if hdr is None else hdr.corr_id
        name = "" if hdr is None else hdr.name
        kind = F.CodeKind.PYBC if hdr is None else hdr.code_kind
        targs = peer.target_args
        if isinstance(targs, dict):
            targs.pop("result", None)
        err = None
        try:
            sts = mb.sweep(peer.target_ctx, targs, budget=1)
        except Exception as e:               # raised *inside* the ifunc
            err = e
            F.scrub_slot(buf)
            mb.head += 1                     # consume the poisoned slot
            mb.consumed += 1
            peer.stats["errors"] += 1
            if not corr:
                raise                        # no future to carry the error
            sts = [Status.OK]                # delivered — it just raised
        if corr and sts and sts[0] in (Status.OK, Status.REJECTED):
            if err is not None:
                value, is_err = err, True
            elif sts[0] == Status.REJECTED:
                value, is_err = TransportError(
                    str(peer.target_ctx.stats.get(
                        "last_reject", "frame rejected"))), True
            else:
                value = targs.get("result") if isinstance(targs, dict) else None
                is_err = False
            self._post_reply(peer, name, kind, corr, value, is_err)
        return sts

    def _post_reply(self, peer: Peer, name: str, kind, corr: int, value,
                    is_err: bool) -> None:
        """Pack a result into a FLAG_REPLY frame and post it target->source.
        The source can always drain its own inbox, so a full reply ring is
        drained inline rather than dropping the result."""
        if peer.reply_channel is None or self.reply_codec is None:
            self.stats["reply_dropped"] += 1
            return
        if peer.reply_credits <= 0:
            self._drain_replies(peer)
        codec = self.reply_codec
        try:
            payload = (codec.encode_error(value) if is_err
                       else codec.encode(value))
        except Exception as e:               # unencodable result: the error
            payload, is_err = codec.encode_error(e), True   # IS the reply
        slab = self.engine.slab_slot(peer.reply_channel, peer.reply_tail)
        try:
            n = F.pack_reply_into(slab, name, payload, kind, corr, err=is_err)
        except F.FrameError as e:            # oversized value: error reply
            n = F.pack_reply_into(slab, name, codec.encode_error(e), kind,
                                  corr, err=True)
        self.engine.post(peer.reply_channel, slab[:n], peer.reply_tail,
                         peer=peer.name)
        peer.reply_tail += 1
        peer.stats["replies"] += 1
        self.stats["replies"] += 1

    def _route_reply(self, corr: int, name: str, value, is_err: bool,
                     decoded: bool) -> None:
        if self.reply_router is None:
            self.stats["reply_dropped"] += 1
            return
        self.reply_router(corr, name, value, is_err, decoded)

    def _drain_replies(self, peer: Peer, budget: int | None = None) -> int:
        """Source side of the reply path: flush the target's pending reply
        puts, then consume FLAG_REPLY frames from the peer's reply ring and
        hand them to the router.  Corrupt reply slots are cleared and
        counted, never wedged."""
        if peer.reply_mailbox is None:
            return 0
        self.engine.flush(peer.reply_channel)
        mb = peer.reply_mailbox
        n = 0
        while budget is None or n < budget:
            buf = mb.slot_view(mb.head)
            try:
                hdr = F.peek_header(buf)
            except F.FrameError:
                F.scrub_slot(buf)
                mb.head += 1
                mb.consumed += 1
                peer.stats["reply_rejects"] = (
                    peer.stats.get("reply_rejects", 0) + 1)
                continue
            if hdr is None or not F.trailer_arrived(buf, hdr):
                break
            payload = bytes(F.frame_sections(buf, hdr)[1])
            corr, name, is_err = hdr.corr_id, hdr.name, hdr.is_err
            F.clear_frame(buf, hdr)
            mb.head += 1
            mb.consumed += 1
            self._route_reply(corr, name, payload, is_err, decoded=False)
            n += 1
        return n

    def poll_replies(self) -> int:
        """Drain every peer's reply ring; returns replies routed."""
        return sum(self._drain_replies(p) for p in self.peers.values())

    def poll(self, budget: int | None = None) -> int:
        """Drain up to ``budget`` messages total across all peers' rings,
        deficit-round-robin.  Each round visits every lane once, consuming
        at most one message per lane per round (so no ring monopolizes the
        poller), starting one lane past last round's first server.  A
        device-mesh lane is the one exception: its sweep is a single
        compiled pass and may yield several messages at once — they all
        count against ``budget``, so the cap can overshoot by one sweep.

        OK deliveries confirm the target's code cache for the frame's
        digest (enabling SLIM framing); NACK_UNCACHED consumes the slot,
        un-confirms the digest, and queues a FULL retransmit.  Replies
        (result-return frames, device sweep results with corr-ids) are
        routed to the reply_router as a side effect; they do not count
        against ``budget``."""
        from repro.core.api import Status

        lanes = self._lanes()
        if not lanes:
            return 0
        done = 0
        self.stats["poll_rounds"] += 1
        progressed = True
        while progressed and (budget is None or done < budget):
            progressed = False
            start = self._rr % len(lanes)
            for k in range(len(lanes)):
                peer, lane = lanes[(start + k) % len(lanes)]
                if budget is not None and done >= budget:
                    break
                track = peer.fabric.kind != "device"
                slot = lane.mailbox.head
                if track and peer.reply_channel is not None:
                    sts = self._sweep_task(peer, lane)
                    coords = res_new = None
                elif track:
                    sts = lane.mailbox.sweep(peer.target_ctx,
                                             peer.target_args, budget=1)
                    coords = res_new = None
                else:
                    res_before = len(getattr(lane.mailbox, "results", ()))
                    sts = lane.mailbox.sweep(peer.target_ctx,
                                             peer.target_args, budget=1)
                    coords = getattr(lane.mailbox, "last_coords", None)
                    res_new = list(getattr(lane.mailbox, "results",
                                           ())[res_before:])
                ri = 0                       # cursor over res_new
                for i, st in enumerate(sts):
                    rec = None
                    coord = (coords[i] if coords is not None
                             and i < len(coords) else None)
                    if st in (Status.OK, Status.REJECTED,
                              Status.NACK_UNCACHED):
                        rec = lane.inflight.pop(slot, None) if track else None
                        slot += 1
                    if st == Status.OK:
                        peer.stats["delivered"] += 1
                        done += 1
                        progressed = True
                        if rec is not None:
                            peer.cached.add(rec.digest)
                        if not track:
                            val = res_new[ri] if ri < len(res_new) else None
                            ri += 1
                            ent = (lane.corr_by_coords.pop(coord, None)
                                   if coord is not None else None)
                            if ent:          # device reply: the result IS it
                                self._route_reply(ent[0], peer.name, val,
                                                  False, decoded=True)
                    elif st == Status.REJECTED:
                        peer.stats["rejected"] += 1
                        done += 1
                        progressed = True
                        if not track and coord is not None:
                            ent = lane.corr_by_coords.pop(coord, None)
                            corr = ent[0] if ent else 0
                            if corr:
                                self._route_reply(
                                    corr, peer.name,
                                    "frame rejected on device sweep",
                                    True, decoded=True)
                    elif st == Status.NACK_UNCACHED:
                        peer.stats["nacks"] += 1
                        self.stats["nacks"] += 1
                        progressed = True
                        if rec is not None and rec.handle is not None:
                            peer.cached.discard(rec.digest)
                            peer.resend.append(
                                self._rebuild_full(lane, slot - 1, rec))
                        else:
                            # a SLIM frame we have no record/handle for (raw
                            # send): nothing to rebuild — surface the loss
                            peer.stats["nack_lost"] = (
                                peer.stats.get("nack_lost", 0) + 1)
                    elif st == Status.IN_PROGRESS:
                        peer.stats["inflight_polls"] += 1
            self._rr += 1
        self.poll_replies()
        self.stats["polled"] += done
        return done

    def _pending_inflight(self) -> int:
        """Tracked frames still awaiting their target's sweep: host-lane
        inflight records (past-consumed records are pruned as a side
        effect) plus device-lane corr-ids awaiting a sweep result."""
        n = 0
        for peer in self.peers.values():
            for lane in peer.rings:
                low = lane.mailbox.consumed
                for s in [s for s in lane.inflight if s < low]:
                    del lane.inflight[s]
                n += len(lane.inflight) + len(lane.corr_by_coords)
            n += len(peer.resend)
        return n

    def fail_inflight(self, reason: str = "liveness deadline exceeded",
                      min_age: float = 0.0) -> int:
        """Give up on tracked in-flight frames at least ``min_age`` seconds
        old: corr-carrying records resolve their futures with a
        TransportError through the reply router (instead of hanging
        forever on a wedged peer); the records and that peer's queued
        retransmits are dropped.  ``min_age`` is what makes this a *per
        frame* liveness floor — a healthy peer actively consuming its
        backlog only has young records, and keeps them.  Returns futures
        failed."""
        now = time.monotonic()
        failed = 0
        for peer in self.peers.values():
            timed_out = 0
            for lane in peer.rings:
                low = lane.mailbox.consumed
                for slot in sorted(lane.inflight):
                    rec = lane.inflight[slot]
                    if slot >= low and now - rec.sent_at < min_age:
                        continue         # young: the peer may still be alive
                    del lane.inflight[slot]
                    if slot < low or not rec.corr_id:
                        continue
                    self._route_reply(
                        rec.corr_id, peer.name,
                        TransportError(
                            f"{rec.name} to {peer.name!r}: {reason} "
                            f"(in flight {now - rec.sent_at:.3f}s)"),
                        True, decoded=True)
                    timed_out += 1
                for coords, (corr, sent_at) in list(
                        lane.corr_by_coords.items()):
                    if now - sent_at < min_age:
                        continue
                    del lane.corr_by_coords[coords]
                    self._route_reply(
                        corr, peer.name,
                        TransportError(
                            f"device lane {peer.name!r}: {reason}"),
                        True, decoded=True)
                    timed_out += 1
            if timed_out:
                while peer.resend:       # retransmits to a dead peer: drop
                    msg = peer.resend.popleft()
                    corr = getattr(msg, "corr_id", 0)
                    if corr:
                        self._route_reply(
                            corr, peer.name,
                            TransportError(
                                f"queued retransmit to {peer.name!r}: "
                                f"{reason}"),
                            True, decoded=True)
                        timed_out += 1
                peer.stats["timed_out"] = (
                    peer.stats.get("timed_out", 0) + timed_out)
                failed += timed_out
        self.stats["timed_out"] = self.stats.get("timed_out", 0) + failed
        return failed

    def drain(self, max_rounds: int = 64, deadline: float | None = None) -> int:
        """flush + poll until quiescent: no outstanding puts, no consumable
        frames, no queued retransmits.  Returns total messages
        delivered/rejected (NACK-retransmitted frames count once, when the
        FULL retry lands).

        ``deadline`` (seconds) is the liveness floor: the drain keeps
        cranking while tracked frames are still in flight (``max_rounds``
        does not apply — the bound is wall time), and once the deadline
        passes it *fails*, via :meth:`fail_inflight`, the futures of
        frames that were in flight for at least the whole deadline —
        frames a peer actively consuming its backlog would have drained.
        Without a deadline, behavior is the historical round-bounded
        quiescence check."""
        t0 = time.monotonic()
        total = 0
        rounds = 0
        while True:
            rounds += 1
            for p in self.peers.values():
                self._flush_resends(p)
            self.engine.progress()
            n = self.poll()
            total += n
            idle = (n == 0 and self.engine.outstanding() == 0
                    and not any(p.resend for p in self.peers.values()))
            if deadline is None:
                if idle or rounds >= max_rounds:
                    break
            else:
                if idle and self._pending_inflight() == 0:
                    break
                if time.monotonic() - t0 >= deadline:
                    self.fail_inflight(
                        f"drain deadline ({deadline:.3g}s) exceeded",
                        min_age=deadline)
                    break
                if idle:
                    time.sleep(0)    # wedged-peer spin: be scheduler-polite
        return total

    # -- reporting ----------------------------------------------------------

    def per_peer_stats(self) -> dict[str, dict]:
        now = time.monotonic()
        return {name: dict(p.stats, credits=p.credits,
                           oldest_inflight_s=round(
                               p.oldest_inflight_age(now), 6))
                for name, p in self.peers.items()}

    def print_stats(self) -> None:
        for p in self.peers.values():
            print(" ", p.summary())


__all__ = ["DEFAULT_N_SLOTS", "DEFAULT_SLOT_SIZE", "Dispatcher", "Peer",
           "RingState"]
