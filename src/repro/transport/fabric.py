"""Pluggable fabric layer: the one transport contract both ifunc universes
(host RDMA emulation and the on-device mailbox/ppermute path) sit on.

Three roles, mirroring a thin UCX:

* :class:`Mailbox`  — a target-owned ring of fixed-size frame slots.  The
  host fabrics expose byte slots polled by ``poll_ifunc``; the device
  fabric exposes word-frame slots swept by the ``ring_poll`` Pallas kernel.
* :class:`Channel`  — a source-side one-sided path into one mailbox.  A
  ``put`` is *non-blocking*: bytes may be partially visible until
  ``flush`` (the real-RDMA in-flight window the frame trailer exists for).
* :class:`Fabric`   — the factory tying the two together for one backend.

Backends here: :class:`RdmaFabric` (wraps ``core/rdma.py``) and
:class:`LoopbackFabric` (zero-copy in-process, for tests/benchmarks and
"CSD-attached" targets).  :class:`DeviceMeshFabric` lives in
``device_fabric.py`` so importing the transport core never drags in jax.

Invariant enforced by this package: nothing outside ``repro.transport``
calls ``Endpoint.put_nbi`` — higher layers (``core/api.py``, the
dispatcher, the pod controller, serving) speak Channel/Mailbox only.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core import frame as F
from repro.core import rdma as R


class TransportError(Exception):
    pass


_API = None      # repro.core.api, imported lazily (api imports codegen,
#                  which the transport core must not drag in at import
#                  time) and memoized — the sweep hot loop must not pay
#                  the import machinery per call


def _api():
    global _API
    if _API is None:
        from repro.core import api
        _API = api
    return _API


# ---------------------------------------------------------------------------
# contracts


class Mailbox:
    """A target-owned ring of ``n_slots`` frame slots of ``slot_size`` bytes.

    ``head`` is the consume index (advanced by the poller); the produce index
    lives with the source-side Channel.  ``consumed`` is the monotone count
    of drained slots — the source reads it to compute returned credits (the
    emulation analogue of a credit-return counter the target writes back).
    """

    fabric: "Fabric"
    n_slots: int
    slot_size: int

    def __init__(self):
        self.head = 0
        self.consumed = 0
        #: coordinates of each status the most recent :meth:`sweep`
        #: returned, in order.  Host mailboxes consume in ring order, so
        #: the produce index is the coordinate; backends that sweep out of
        #: order (the device mesh) override :meth:`slot_coords` and fill
        #: this with their native coordinates — the reply demux correlates
        #: sweep results to task corr-ids through it.
        self.last_coords: list = []
        #: per-sub-record outcomes of consumed FLAG_AGG frames, keyed by
        #: the slot coordinate the container occupied (host coordinates
        #: are the monotone absolute produce index, so keys never repeat
        #: on ring wrap): coordinate -> list[api.AggSubResult].  Filled by
        #: :meth:`sweep` (from ``ctx.last_agg_results``), popped by the
        #: dispatcher's aggregate completion; bounded so a sweep-only
        #: caller that ignores aggregates cannot leak entries.
        self.last_agg: dict = {}
        #: an ifunc exception raised by a slot *behind* frames this sweep
        #: already consumed: the batch stops, the consumed frames' statuses
        #: are returned (their completions must not be lost), and the
        #: caller (Dispatcher.poll) re-raises this after processing them.
        #: The poisoned slot itself is NOT consumed — exactly the
        #: historical budget=1 behavior, where the raise surfaced on the
        #: poll that reached the slot.
        self.pending_raise: BaseException | None = None
        #: in-progress FLAG_STREAM receive state, keyed by the slot
        #: coordinate the stream frame occupies: coordinate ->
        #: ``api._StreamRx``.  Owned by ``poll_ifunc`` (created on the
        #: descriptor's arrival, popped at completion/rejection); the
        #: mailbox carries it because stream lifetime spans many sweeps
        #: of one slot.  ``stream_consumed`` exposes the consume counter
        #: the source's chunk pump reads for window flow control.
        self.streams: dict = {}

    def slot_coords(self, i: int):
        """Stable coordinate a produce-index maps to (what ``last_coords``
        entries are keyed by).  Identity for in-order host rings."""
        return i

    def stream_consumed(self, coords) -> int:
        """Number of chunks the stream at ``coords`` has consumed — the
        credit-return counter the source's chunk pump polls before
        overwriting a window cell (a cell is reusable once the chunk
        ``window`` positions behind it has been consumed).  0 until the
        stream descriptor has been polled."""
        rx = self.streams.get(coords)
        return 0 if rx is None else rx.next_seq

    def slot_view(self, i: int) -> memoryview:
        raise NotImplementedError

    def peek(self):
        """Best-effort parsed header of the frame at ``head``, or None when
        the slot is empty/unparsable or the backend exposes no byte view
        (the device mesh).  Part of the fabric contract: the dispatcher's
        reply and flow paths read corr/flags *ahead of* the consuming sweep
        through this instead of duck-typing into backend internals —
        corruption surfaces later, on the sweep itself."""
        try:
            return F.peek_header(self.slot_view(self.head))
        except (F.FrameError, TransportError, NotImplementedError):
            return None

    def sweep(self, ctx, target_args, budget: int | None = None) -> list:
        """Drain up to ``budget`` slots through ``poll_ifunc``; returns the
        list of per-slot Status values observed.  OK/REJECTED/NACK_UNCACHED
        all consume the slot and advance head (a NACKed SLIM frame is
        cleared — the retransmit arrives as a fresh FULL frame).

        Note: the *recovery* half of the NACK protocol (rebuilding and
        requeueing the FULL frame) lives in ``Dispatcher.poll``; a caller
        sweeping a mailbox directly must either send FULL frames only (the
        default until a dispatcher confirms the peer) or handle
        NACK_UNCACHED in the returned statuses itself."""
        A = _api()

        out = []
        budget = self.n_slots if budget is None else budget
        obs = getattr(ctx, "obs", None)
        t0 = (time.perf_counter() if obs is not None and obs.enabled
              else None)
        consumed0 = self.consumed
        for _ in range(budget):
            try:
                st = A.poll_ifunc(ctx, self.slot_view(self.head), None,
                                  target_args, streams=self.streams,
                                  stream_key=self.slot_coords(self.head))
            except Exception as e:       # raised *inside* an ifunc
                if not out:
                    raise                # first slot: historical behavior
                self.pending_raise = e   # mid-batch: don't discard the
                break                    # consumed frames' statuses
            out.append(st)
            agg = getattr(ctx, "last_agg_results", None)
            if agg is not None:
                # a FLAG_AGG container was consumed at this slot: stash its
                # per-sub-record outcomes under the slot's coordinate for
                # the dispatcher's aggregate completion pass
                self.last_agg[self.slot_coords(self.head)] = agg
                ctx.last_agg_results = None
                while len(self.last_agg) > 2 * self.n_slots:
                    self.last_agg.pop(next(iter(self.last_agg)))
            if st in (A.Status.OK, A.Status.REJECTED, A.Status.NACK_UNCACHED):
                self.head += 1
                self.consumed += 1
            else:
                break
        if t0 is not None and self.consumed != consumed0:
            # only sweeps that consumed something observe: idle polls would
            # otherwise flood the distribution with empty-peek latencies
            obs.sweep_hist.observe((time.perf_counter() - t0) * 1e6)
        return out


class Channel:
    """Source-side one-sided path into one remote Mailbox."""

    mailbox: Mailbox

    def __init__(self):
        self.stats = {"puts": 0, "bytes": 0, "flushes": 0, "partial": 0}

    def put(self, data, slot: int, *, deliver_bytes: int | None = None) -> None:
        """Non-blocking write of ``data`` into ring slot ``slot``.  With
        ``deliver_bytes`` only a prefix is visible until :meth:`flush` —
        the ProgressEngine uses this to model in-flight puts."""
        raise NotImplementedError

    def put_at(self, data, slot: int, offset: int, *,
               deliver_bytes: int | None = None) -> None:
        """Non-blocking write of ``data`` at byte ``offset`` *within* ring
        slot ``slot`` — the streamed-payload path's chunk put (and the
        stream open's withheld frame trailer).  Same delivery semantics as
        :meth:`put`; ``deliver_bytes=0`` withholds the entire write until
        :meth:`flush` (a chunk seal / trailer barrier).  Backends without
        sub-slot addressing (the device mesh) don't implement it — streams
        are a host-tier feature, like continuations."""
        raise NotImplementedError

    def putv_at(self, segs, slot: int, *, withhold_tail: int = 0) -> None:
        """Scatter-gather write into ring slot ``slot``: ``segs`` is a
        sequence of ``(offset, data)`` pairs posted as ONE work request.
        ``withhold_tail`` keeps the last N bytes of the final segment
        invisible until :meth:`flush` — callers order the barrier bytes
        (frame trailer, chunk seal) last.  The generic fallback degrades
        to one :meth:`put_at` per segment; RDMA-class backends override
        with a true multi-SGE posting."""
        last = len(segs) - 1
        for i, (off, d) in enumerate(segs):
            db = None
            if withhold_tail and i == last:
                db = max(len(d) - withhold_tail, 0)
            self.put_at(d, slot, off, deliver_bytes=db)

    def flush(self) -> None:
        raise NotImplementedError


class Fabric:
    """One transport backend: makes mailboxes on targets, channels to them."""

    kind: str = "abstract"

    def open_mailbox(self, target_ctx, n_slots: int, slot_size: int) -> Mailbox:
        raise NotImplementedError

    def connect(self, src_ctx, mailbox: Mailbox) -> Channel:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# RDMA fabric (wraps core/rdma.py)


class RdmaMailbox(Mailbox):
    """Composes the existing rdma.RingBuffer for all slot math."""

    def __init__(self, fabric: "RdmaFabric", region: R.MemRegion, slot_size: int):
        super().__init__()
        self.fabric = fabric
        self.region = region
        self.ring = R.RingBuffer(region, slot_size)
        self.slot_size = slot_size
        self.n_slots = self.ring.n_slots

    def slot_addr(self, i: int) -> int:
        return self.ring.slot_addr(i)

    def slot_view(self, i: int) -> memoryview:
        return self.ring.slot_view(i)


class RdmaChannel(Channel):
    def __init__(self, ep: R.Endpoint, mailbox: RdmaMailbox):
        super().__init__()
        self.ep = ep
        self.mailbox = mailbox

    def put(self, data, slot: int, *, deliver_bytes: int | None = None) -> None:
        if len(data) > self.mailbox.slot_size:
            raise TransportError(
                f"frame {len(data)}B exceeds slot {self.mailbox.slot_size}B")
        self.ep.put_nbi(data, self.mailbox.slot_addr(slot),
                        self.mailbox.region.rkey, deliver_bytes=deliver_bytes)
        self.stats["puts"] += 1
        self.stats["bytes"] += len(data)
        if deliver_bytes is not None and deliver_bytes < len(data):
            self.stats["partial"] += 1

    def put_at(self, data, slot: int, offset: int, *,
               deliver_bytes: int | None = None) -> None:
        if offset + len(data) > self.mailbox.slot_size:
            raise TransportError(
                f"put_at [{offset}, {offset + len(data)}) exceeds slot "
                f"{self.mailbox.slot_size}B")
        self.ep.put_nbi(data, self.mailbox.slot_addr(slot) + offset,
                        self.mailbox.region.rkey, deliver_bytes=deliver_bytes)
        self.stats["puts"] += 1
        self.stats["bytes"] += len(data)
        if deliver_bytes is not None and deliver_bytes < len(data):
            self.stats["partial"] += 1

    def putv_at(self, segs, slot: int, *, withhold_tail: int = 0) -> None:
        extent = 0
        nbytes = 0
        for off, d in segs:
            nbytes += len(d)
            end = off + len(d)
            extent = end if end > extent else extent
        if extent > self.mailbox.slot_size:
            raise TransportError(
                f"putv extent {extent}B exceeds slot "
                f"{self.mailbox.slot_size}B")
        self.ep.putv_nbi(segs, self.mailbox.slot_addr(slot),
                         self.mailbox.region.rkey,
                         withhold_tail=withhold_tail)
        self.stats["puts"] += 1
        self.stats["bytes"] += nbytes
        if withhold_tail:
            self.stats["partial"] += 1

    def put_raw(self, data, remote_addr: int, rkey: int, *,
                deliver_bytes: int | None = None) -> None:
        """Address-directed put for legacy callers (``ifunc_msg_send_nbix``
        with an explicit remote_addr/rkey, the AM baseline's eager slots)."""
        self.ep.put_nbi(data, remote_addr, rkey, deliver_bytes=deliver_bytes)
        self.stats["puts"] += 1
        self.stats["bytes"] += len(data)

    def flush(self) -> None:
        self.ep.flush()
        self.stats["flushes"] += 1


class RdmaFabric(Fabric):
    """Emulated-RDMA backend: mailboxes are ``mem_map``-ed regions, channels
    are NIC endpoints; every inbound put is rkey/bounds-checked by the
    'HCA' before any byte moves."""

    kind = "rdma"

    def open_mailbox(self, target_ctx, n_slots: int, slot_size: int) -> RdmaMailbox:
        nic = target_ctx.nic if hasattr(target_ctx, "nic") else target_ctx
        region = nic.mem_map(n_slots * slot_size)
        return RdmaMailbox(self, region, slot_size)

    def connect(self, src_ctx, mailbox: RdmaMailbox) -> RdmaChannel:
        nic = src_ctx.nic if hasattr(src_ctx, "nic") else src_ctx
        return RdmaChannel(nic.connect(mailbox.region.nic), mailbox)

    @staticmethod
    def channel_for_endpoint(ep: R.Endpoint) -> "RdmaChannel":
        """Wrap a bare Endpoint for address-directed legacy sends (no ring)."""
        ch = RdmaChannel.__new__(RdmaChannel)
        Channel.__init__(ch)
        ch.ep = ep
        ch.mailbox = None
        return ch


# ---------------------------------------------------------------------------
# Loopback fabric (zero-copy in-process; the "CSD" / test backend)


@dataclass
class _PendingLoopPut:
    buf: bytearray
    off: int            # where the withheld tail lands at flush
    tail: bytes


class LoopbackMailbox(Mailbox):
    def __init__(self, fabric: "LoopbackFabric", n_slots: int, slot_size: int):
        super().__init__()
        self.fabric = fabric
        self.n_slots, self.slot_size = n_slots, slot_size
        self.buf = bytearray(n_slots * slot_size)

    def slot_view(self, i: int) -> memoryview:
        off = (i % self.n_slots) * self.slot_size
        return memoryview(self.buf)[off:off + self.slot_size]


class LoopbackChannel(Channel):
    def __init__(self, mailbox: LoopbackMailbox):
        super().__init__()
        self.mailbox = mailbox
        self._pending: list[_PendingLoopPut] = []

    def put(self, data, slot: int, *, deliver_bytes: int | None = None) -> None:
        mb = self.mailbox
        nd = len(data)
        if nd > mb.slot_size:
            raise TransportError(
                f"frame {nd}B exceeds slot {mb.slot_size}B")
        off = (slot % mb.n_slots) * mb.slot_size
        mv = data if isinstance(data, memoryview) else memoryview(data)
        n = nd if deliver_bytes is None else min(deliver_bytes, nd)
        mb.buf[off:off + n] = mv[:n]
        if n < nd:
            self._pending.append(_PendingLoopPut(mb.buf, off + n, bytes(mv[n:])))
            self.stats["partial"] += 1
        self.stats["puts"] += 1
        self.stats["bytes"] += nd

    def put_at(self, data, slot: int, offset: int, *,
               deliver_bytes: int | None = None) -> None:
        mb = self.mailbox
        nd = len(data)
        if offset + nd > mb.slot_size:
            raise TransportError(
                f"put_at [{offset}, {offset + nd}) exceeds slot "
                f"{mb.slot_size}B")
        off = (slot % mb.n_slots) * mb.slot_size + offset
        mv = data if isinstance(data, memoryview) else memoryview(data)
        n = nd if deliver_bytes is None else min(deliver_bytes, nd)
        if n:
            mb.buf[off:off + n] = mv[:n]
        if n < nd:
            self._pending.append(_PendingLoopPut(mb.buf, off + n, bytes(mv[n:])))
            self.stats["partial"] += 1
        self.stats["puts"] += 1
        self.stats["bytes"] += nd

    def putv_at(self, segs, slot: int, *, withhold_tail: int = 0) -> None:
        mb = self.mailbox
        base = (slot % mb.n_slots) * mb.slot_size
        last = len(segs) - 1
        nbytes = 0
        for i, (off, d) in enumerate(segs):
            mv = d if isinstance(d, memoryview) else memoryview(d)
            nd = len(mv)
            nbytes += nd
            if off + nd > mb.slot_size:
                raise TransportError(
                    f"putv [{off}, {off + nd}) exceeds slot "
                    f"{mb.slot_size}B")
            n = max(nd - withhold_tail, 0) if withhold_tail and i == last \
                else nd
            if n:
                mb.buf[base + off:base + off + n] = mv[:n]
            if n < nd:
                self._pending.append(
                    _PendingLoopPut(mb.buf, base + off + n, bytes(mv[n:])))
                self.stats["partial"] += 1
        self.stats["puts"] += 1
        self.stats["bytes"] += nbytes

    def flush(self) -> None:
        for p in self._pending:
            p.buf[p.off:p.off + len(p.tail)] = p.tail
        self._pending.clear()
        self.stats["flushes"] += 1


class LoopbackFabric(Fabric):
    """In-process zero-copy backend: no NIC, no rkeys — the floor every
    latency number should be compared against, and the stand-in for
    bus-attached targets (CSDs) whose 'network' is a memory bus."""

    kind = "loopback"

    def open_mailbox(self, target_ctx, n_slots: int, slot_size: int) -> LoopbackMailbox:
        return LoopbackMailbox(self, n_slots, slot_size)

    def connect(self, src_ctx, mailbox: LoopbackMailbox) -> LoopbackChannel:
        return LoopbackChannel(mailbox)


class LegacyRingMailbox(Mailbox):
    """Adapter: an existing ``rdma.RingBuffer`` viewed as a transport
    Mailbox, so the deprecated ``poll_ring`` API drains through the same
    sweep path as everything else.  Head state stays on the RingBuffer."""

    def __init__(self, ring: R.RingBuffer):
        Mailbox.__init__(self)
        self.ring = ring
        self.n_slots = ring.n_slots
        self.slot_size = ring.slot_size

    @property
    def head(self) -> int:
        return self.ring.head

    @head.setter
    def head(self, v: int) -> None:
        # Mailbox.__init__ assigns head=0 before self.ring exists; swallow it.
        if hasattr(self, "ring"):
            self.ring.head = v

    def slot_view(self, i: int) -> memoryview:
        return self.ring.slot_view(i)


def ring_mailbox(ring: R.RingBuffer) -> LegacyRingMailbox:
    """Cached LegacyRingMailbox for a RingBuffer (keeps ``consumed`` stable
    across calls so credit math works)."""
    mb = getattr(ring, "_transport_mailbox", None)
    if mb is None:
        mb = LegacyRingMailbox(ring)
        ring._transport_mailbox = mb
    return mb


def endpoint_channel(ep: R.Endpoint) -> RdmaChannel:
    """Cached raw channel for a bare Endpoint (legacy address-directed
    sends route through the transport layer via this)."""
    ch = getattr(ep, "_transport_channel", None)
    if ch is None:
        ch = RdmaFabric.channel_for_endpoint(ep)
        ep._transport_channel = ch
    return ch


def frame_fits(frame, mailbox: Mailbox) -> bool:
    return len(frame) <= mailbox.slot_size


__all__ = [
    "Channel", "Fabric", "LegacyRingMailbox", "Mailbox", "TransportError",
    "LoopbackChannel", "LoopbackFabric", "LoopbackMailbox",
    "RdmaChannel", "RdmaFabric", "RdmaMailbox",
    "endpoint_channel", "frame_fits", "ring_mailbox",
]
