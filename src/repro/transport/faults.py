"""Deterministic fault injection for the transport layer.

Recovery code that can only be exercised by racing a real death against a
real poll loop is untestable; the :class:`FaultInjector` makes peer death,
heartbeat loss, and put loss *deterministic* so `tests/test_elastic.py`
and the ``fig_elastic`` bench can replay the exact same failure on every
run.  It is pure bookkeeping — the transport consults it at three choke
points and the injector never touches a buffer itself:

* ``kill_peer(name, after_delivered=N)`` — the peer is considered down
  once its dispatcher has seen N delivered frames for it (N=0: down now).
  ``Dispatcher.poll`` stops sweeping a down peer's mailboxes (frames
  already posted sit undelivered, exactly like a crashed process whose
  progress thread died), and ``ElasticController`` stops executing its
  beats, so death is observed the same way a real one would be: the
  heartbeat deadline lapses.
* ``delay_heartbeats(name, beats=k)`` — swallow the next k beats from a
  live peer (a GC pause / link flap, not a death); lets tests pin the
  deadline boundary.
* ``drop_put(name, kth)`` — the k-th subsequent ``_post_view`` for the
  peer vanishes on the wire: the tx record and tail advance stay (the
  source believes it posted), the bytes never land.  Exercises the
  liveness timeout -> ``fail_inflight`` path for a *lost* frame rather
  than a dead peer.

All counters are per-peer and monotone; a tripped kill stays tripped
until ``revive(name)`` (re-admission) clears it.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class _PeerFaults:
    kill_after: int | None = None   # delivered-frame threshold, None = never
    delivered: int = 0              # frames delivered so far (dispatcher-fed)
    down: bool = False              # latched once the threshold is crossed
    delay_beats: int = 0            # beats left to swallow
    drop_kth: int | None = None     # 1-based index of the put to drop
    puts_seen: int = 0              # puts observed since drop_put() was armed


class FaultInjector:
    """Deterministic per-peer fault schedule consulted by the transport."""

    def __init__(self) -> None:
        self._peers: dict[str, _PeerFaults] = {}
        self.stats = {"kills": 0, "dropped_puts": 0, "delayed_beats": 0}

    def _p(self, name: str) -> _PeerFaults:
        p = self._peers.get(name)
        if p is None:
            p = self._peers[name] = _PeerFaults()
        return p

    # -- schedule side ------------------------------------------------------

    def kill_peer(self, name: str, after_delivered: int = 0) -> None:
        """Peer ``name`` dies once ``after_delivered`` frames have been
        delivered to it (0 = immediately)."""
        p = self._p(name)
        p.kill_after = after_delivered
        if p.delivered >= after_delivered:
            self._trip(p)

    def delay_heartbeats(self, name: str, beats: int = 1) -> None:
        """Swallow the next ``beats`` heartbeats from ``name``."""
        self._p(name).delay_beats += beats

    def drop_put(self, name: str, kth: int = 1) -> None:
        """Drop the ``kth`` put posted to ``name`` from now (1-based)."""
        p = self._p(name)
        p.drop_kth = kth
        p.puts_seen = 0

    def revive(self, name: str) -> None:
        """Clear a latched kill (the peer restarted and is re-admitted)."""
        p = self._peers.get(name)
        if p is not None:
            p.down = False
            p.kill_after = None

    # -- transport side -----------------------------------------------------

    def _trip(self, p: _PeerFaults) -> None:
        if not p.down:
            p.down = True
            self.stats["kills"] += 1

    def is_down(self, name: str, delivered: int | None = None) -> bool:
        """True once the peer's kill threshold has been crossed.  The
        dispatcher feeds its running delivered-frame count; the latch keeps
        the answer stable for callers (controller, tests) that don't."""
        p = self._peers.get(name)
        if p is None:
            return False
        if delivered is not None:
            p.delivered = max(p.delivered, delivered)
        if (not p.down and p.kill_after is not None
                and p.delivered >= p.kill_after):
            self._trip(p)
        return p.down

    def should_drop_beat(self, name: str) -> bool:
        """Consume one scheduled heartbeat delay, if any."""
        p = self._peers.get(name)
        if p is not None and p.delay_beats > 0:
            p.delay_beats -= 1
            self.stats["delayed_beats"] += 1
            return True
        return False

    def should_drop_put(self, name: str) -> bool:
        """Consume the armed k-th-put drop when this put is the k-th."""
        p = self._peers.get(name)
        if p is None or p.drop_kth is None:
            return False
        p.puts_seen += 1
        if p.puts_seen == p.drop_kth:
            p.drop_kth = None
            self.stats["dropped_puts"] += 1
            return True
        return False
