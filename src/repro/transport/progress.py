"""Progress engine: completion queues + batched non-blocking puts.

``put_nbi`` is non-blocking by contract; the engine makes the resulting
in-flight window a *first-class state* instead of a test knob:

* every posted put gets a :class:`TxHandle`; its completion lands on the
  engine's completion queue only when the owning channel is flushed;
* with ``inflight_window`` set, the engine withholds the frame's trailing
  bytes (default: the 4-byte trailer signal) until flush — so a target
  polling mid-put observes ``Status.IN_PROGRESS`` exactly as on real RDMA
  hardware, and the flush is what publishes the trailer;
* puts batch: channels auto-flush after ``flush_threshold`` outstanding
  puts, or explicitly via :meth:`flush` / :meth:`progress`.

Completion callbacks (callback-on-flush semantics) fire when the handle
completes, in post order per channel.

The engine also owns the *send slabs*: one preallocated staging buffer per
channel, one slot-sized cell per ring slot.  The dispatcher packs frames
directly into slab cells (``frame.pack_frame_into``/``seal_frame``) and
posts the resulting memoryview — no per-message bytearray is ever
allocated on the send path.  A cell is stable exactly as long as its ring
slot's credit is outstanding, which is precisely the lifetime an in-flight
put needs.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.core import frame as F
from repro.transport.fabric import Channel

_TRAILER_BYTES = F.TRAILER.to_bytes(F.TRAILER_LEN, "little")


@dataclass
class TxHandle:
    """One posted put: completes (callback + CQ entry) at flush time.

    ``future`` optionally ties the put to a task-runtime Future — or, for
    an aggregate container carrying several coalesced corr_ids, a
    list/tuple of them: the flush that publishes the frame marks every
    tied future SENT (its reply clock starts only once the request is
    actually visible at the target)."""

    seq: int
    channel: Channel
    nbytes: int
    slot: int
    peer: str | None = None
    done: bool = False
    on_complete: object = None
    future: object = None


@dataclass
class Completion:
    seq: int
    peer: str | None
    nbytes: int
    slot: int


class ProgressEngine:
    """ucp_worker analogue: owns outstanding puts across all channels.

    ``inflight_window``: None posts puts fully delivered (eager, loopback
    semantics).  An int N withholds the last N bytes of every frame until
    flush; ``"trailer"`` withholds exactly the frame trailer signal — the
    paper's delivery-barrier window.
    """

    def __init__(self, flush_threshold: int = 8,
                 inflight_window: int | str | None = "trailer"):
        self.flush_threshold = flush_threshold
        self.inflight_window = inflight_window
        self.completion_queue: deque[Completion] = deque()
        self._outstanding: dict[int, list[TxHandle]] = {}  # id(channel) -> handles
        self._channels: dict[int, Channel] = {}
        self._slabs: dict[int, tuple[bytearray, int, int]] = {}
        self._seq = 0
        self.stats = {"posted": 0, "completed": 0, "flushes": 0,
                      "auto_flushes": 0, "callbacks": 0, "slab_bytes": 0,
                      "futures_sent": 0}
        #: repro.obs.Obs bundle — installed by the owning Dispatcher so
        #: flush spans land in the same trace as its put/poll spans
        self.obs = None

    # -- send slabs ---------------------------------------------------------

    #: extra bytes per slab cell beyond the mailbox slot size — covers
    #: backends (device mesh) whose wire-frame header is larger than their
    #: on-target slot encoding.  Slot-size enforcement stays with the
    #: channel's put; the slab is pure staging capacity.
    SLAB_HEADROOM = 256

    def slab_slot(self, channel: Channel, slot: int) -> memoryview:
        """Writable slot-sized staging cell for ``slot`` of the channel's
        mailbox ring.  Allocated once per channel (n_slots x cell) and
        reused for the channel's lifetime; the cell for a slot may be
        rewritten only after that slot's credit returned, which makes it
        stable across the in-flight window of the put it backs."""
        key = id(channel)
        ent = self._slabs.get(key)
        if ent is None:
            mb = channel.mailbox
            cell = mb.slot_size + self.SLAB_HEADROOM
            slab = bytearray(mb.n_slots * cell)
            ent = (slab, mb.n_slots, cell)
            self._slabs[key] = ent
            self.stats["slab_bytes"] += len(slab)
        slab, n_slots, cell = ent
        off = (slot % n_slots) * cell
        return memoryview(slab)[off:off + cell]

    def release_slab(self, channel: Channel) -> None:
        """Drop a removed peer's staging slab (see Dispatcher.remove_peer)."""
        ent = self._slabs.pop(id(channel), None)
        if ent is not None:
            self.stats["slab_bytes"] -= len(ent[0])

    # -- source side --------------------------------------------------------

    def _window(self, nbytes: int) -> int | None:
        w = self.inflight_window
        if w is None:
            return None
        if w == "trailer":
            return max(nbytes - F.TRAILER_LEN, 0)
        return max(nbytes - int(w), 0)

    def post(self, channel: Channel, frame, slot: int, *,
             peer: str | None = None, on_complete=None,
             future=None) -> TxHandle:
        """Non-blocking send of one frame into ``slot`` of the channel's
        mailbox.  Returns a handle; the frame is not guaranteed visible at
        the target until the handle completes.  ``future`` (a task-runtime
        Future) is marked SENT when this put's flush publishes the frame."""
        self._seq += 1
        h = TxHandle(self._seq, channel, len(frame), slot, peer=peer,
                     on_complete=on_complete, future=future)
        channel.put(frame, slot, deliver_bytes=self._window(len(frame)))
        self._register(channel, h)
        return h

    def _register(self, channel: Channel, h: TxHandle) -> None:
        key = id(channel)
        self._channels[key] = channel
        self._outstanding.setdefault(key, []).append(h)
        self.stats["posted"] += 1
        if len(self._outstanding[key]) >= self.flush_threshold:
            self.stats["auto_flushes"] += 1
            self.flush(channel)

    # -- streamed large payloads (frame v2.5) -------------------------------

    def post_stream_open(self, channel: Channel, prefix, frame_len: int,
                         slot: int, *, peer: str | None = None,
                         on_complete=None, future=None) -> TxHandle:
        """Open a FLAG_STREAM frame: put the small prefix (header + code +
        descriptor) and the frame trailer, withholding the trailer until
        flush — the descriptor barrier.  The ``window x cell`` gap between
        prefix and trailer is never written: ring slots arrive zeroed (the
        previous frame's clear) and chunk tags disambiguate the cells."""
        self._seq += 1
        h = TxHandle(self._seq, channel, len(prefix) + F.TRAILER_LEN, slot,
                     peer=peer, on_complete=on_complete, future=future)
        channel.putv_at(
            [(0, prefix), (frame_len - F.TRAILER_LEN, _TRAILER_BYTES)],
            slot,
            withhold_tail=0 if self.inflight_window is None
            else F.TRAILER_LEN)
        self._register(channel, h)
        return h

    def post_stream_frame(self, channel: Channel, slot: int, segs,
                          frame_len: int, *, peer: str | None = None,
                          on_complete=None, future=None) -> TxHandle:
        """Eager stream open: when every chunk of a FLAG_STREAM frame is
        available at open time and fits the frame's cell window, the whole
        frame — prefix, each cell's header|data|seal, and the frame
        trailer — posts as ONE scatter-gather work request instead of
        ``2 + 3 x n_chunks`` separate puts.  The chunk data segments are
        views straight into the caller's payload (zero-copy), and the
        trailer rides last with its tail withheld until flush, so the
        descriptor barrier is unchanged: a target polling mid-put still
        sees IN_PROGRESS until the flush publishes the frame."""
        self._seq += 1
        segs = list(segs)
        segs.append((frame_len - F.TRAILER_LEN, _TRAILER_BYTES))
        nbytes = 0
        for _, d in segs:
            nbytes += len(d)
        h = TxHandle(self._seq, channel, nbytes, slot, peer=peer,
                     on_complete=on_complete, future=future)
        channel.putv_at(segs, slot,
                        withhold_tail=0 if self.inflight_window is None
                        else F.TRAILER_LEN)
        self._register(channel, h)
        return h

    def post_chunk(self, channel: Channel, slot: int, cell_off: int,
                   hdr, data, seal, *, peer: str | None = None,
                   on_complete=None, future=None) -> TxHandle:
        """Post one stream chunk: header, zero-copy data, and the 4-byte
        seal as ONE scatter-gather put, the seal's bytes withheld until
        flush — so the flush that publishes the seal is the chunk's
        delivery barrier (the frame's trailer-withholding, generalized to
        chunk boundaries).  ``data`` may be a view straight into the
        caller's payload (the streamed path's zero-copy contract: the
        engine never stages chunk bytes)."""
        self._seq += 1
        h = TxHandle(self._seq, channel, len(hdr) + len(data) + len(seal),
                     slot, peer=peer, on_complete=on_complete, future=future)
        channel.putv_at(
            [(cell_off, hdr), (cell_off + len(hdr), data),
             (cell_off + len(hdr) + len(data), seal)],
            slot,
            withhold_tail=0 if self.inflight_window is None else len(seal))
        self._register(channel, h)
        return h

    def flush(self, channel: Channel | None = None) -> int:
        """Complete outstanding puts (all channels when ``channel`` is None).
        Publishes withheld bytes, fires callbacks in post order, pushes CQ
        entries.  Returns the number of completions."""
        keys = [id(channel)] if channel is not None else list(self._outstanding)
        n = 0
        o = self.obs
        sp = None
        if (o is not None and o.enabled and o.tracer.enabled
                and any(self._outstanding.get(k) for k in keys)):
            sp = o.tracer.begin("flush", cat="engine",
                                actor="engine",
                                channels=sum(1 for k in keys
                                             if self._outstanding.get(k)))
        for key in keys:
            handles = self._outstanding.pop(key, [])
            if not handles:
                continue
            # drop the channel ref once drained (re-registered on next post)
            # so removed peers' rings don't stay reachable from the engine
            ch = self._channels.pop(key)
            ch.flush()
            for h in handles:
                h.done = True
                self.completion_queue.append(
                    Completion(h.seq, h.peer, h.nbytes, h.slot))
                if h.future is not None:
                    futs = (h.future if isinstance(h.future, (list, tuple))
                            else (h.future,))
                    for f in futs:
                        f._mark_sent(h.seq)
                    self.stats["futures_sent"] += len(futs)
                if h.on_complete is not None:
                    h.on_complete(h)
                    self.stats["callbacks"] += 1
                n += 1
        self.stats["completed"] += n
        self.stats["flushes"] += 1
        if sp is not None:
            o.tracer.end(sp, completions=n)
        return n

    def progress(self) -> int:
        """Advance everything that can advance without blocking: flush every
        channel with outstanding puts.  Returns completions produced."""
        return self.flush(None) if self._outstanding else 0

    # -- completion queue ---------------------------------------------------

    def outstanding(self, channel: Channel | None = None) -> int:
        if channel is not None:
            return len(self._outstanding.get(id(channel), []))
        return sum(len(v) for v in self._outstanding.values())

    def poll_cq(self, max_n: int | None = None) -> list[Completion]:
        out = []
        while self.completion_queue and (max_n is None or len(out) < max_n):
            out.append(self.completion_queue.popleft())
        return out


__all__ = ["Completion", "ProgressEngine", "TxHandle"]
