"""Device-mesh fabric: the on-device mailbox/ppermute ifunc path behind the
same Fabric/Channel/Mailbox contract as the host backends.

The backend wraps ``core/device_mailbox.py``: a mailbox is a ring of
word-frames in (emulated) device memory per mesh shard; a put *transcodes*
the wire byte-frame (header + μVM code + f32 payload + trailer) into the
device word-frame layout — the NIC-offload moment — and stages it; flush
deposits every staged generation over the ICI via ``ppermute`` (the
RDMA-put analogue); the sweep validates all slots in one compiled
``ring_poll`` + ``ifunc_vm`` pass with the μVM program bound at
mailbox-open time (the device-side link cache).

Visibility is generation-batched: frames become consumable only after the
depositing flush, which is exactly the in-flight window the ProgressEngine
models on the host fabrics.  Deposits are slot-masked (only written slots
land), so flushing a new generation never clobbers deposited frames a
sweep has not consumed yet.

Kept in its own module so ``repro.transport`` imports without jax.
"""

from __future__ import annotations

import numpy as np

from repro.core import frame as F
from repro.transport.fabric import Channel, Fabric, Mailbox, TransportError


class DeviceMeshMailbox(Mailbox):
    """Ring of word-frame slots on every shard of a 1-D device mesh."""

    def __init__(self, fabric: "DeviceMeshFabric", mesh, axis: str, prog,
                 externals, n_slots: int, n_tiles: int, tile: int = 128,
                 *, interpret: bool = True, shift: int = 0,
                 agg_k: int = 0, prog_name: str | None = None):
        super().__init__()
        from repro.core.device_mailbox import (empty_mailbox, make_agg_sweep,
                                               make_deposit, make_sweep)
        from repro.kernels.ring_poll import HDR_WORDS

        self.fabric = fabric
        self.mesh, self.axis, self.shift = mesh, axis, shift
        self.n_shards = mesh.shape[axis]
        self.n_slots_per_shard = n_slots
        self.n_slots = n_slots * self.n_shards       # dispatcher-visible ring
        self.n_tiles, self.tile = n_tiles, tile
        self.body_words = n_tiles * tile * tile
        self.agg_k = agg_k
        self.prog_name = prog_name
        self.bound_hash = (F.fletcher32(prog_name.encode()) & 0xFFFFFFFF
                           if prog_name else 0)
        if agg_k:
            # aggregate container slot: hdr + K descriptor pairs + K bodies
            # + fixed-tail trailer (kernels/agg_poll.py layout)
            self.slot_words = (HDR_WORDS + 2 * agg_k
                               + agg_k * self.body_words + 1)
            # byte-frame capacity the dispatcher budgets containers against:
            # container header/trailer + counts + per-sub (name-table entry
            # + sub-record + body bytes) + signal
            self.slot_size = (F.HEADER_LEN + F.TRAILER_LEN + 8
                              + agg_k * (33 + F.AGG_SUB_OVERHEAD
                                         + self.body_words * 4) + 4)
        else:
            self.slot_words = HDR_WORDS + self.body_words + 1
            self.slot_size = self.slot_words * 4     # byte-equivalent capacity
        self.prog = prog
        self.externals = externals                   # [n_shards, n_ext, T, T]
        self._mb = empty_mailbox(self.n_shards, n_slots, self.slot_words)
        self._deposit = make_deposit(mesh, axis)
        if agg_k:
            self._sweep = make_agg_sweep(mesh, axis, prog, agg_k, n_tiles,
                                         tile, bound_hash=self.bound_hash,
                                         interpret=interpret)
        else:
            self._sweep = make_sweep(mesh, axis, prog, n_tiles, tile,
                                     interpret=interpret)
        self._staged: np.ndarray | None = None
        self._staged_count = 0
        self._deposited = 0                          # frames awaiting sweep
        self.results: list = []                      # READY outputs, one entry
        #                                 per consumed container/singleton
        self.last_coords: list[tuple[int, int]] = []  # (shard, slot) per
        #                                 status of the most recent sweep —
        #                                 the reply demux correlates device
        #                                 results to task corr-ids with this

    @property
    def supports_agg(self) -> bool:
        """Aggregate containers transcode onto this lane (the dispatcher's
        eligibility probe)."""
        return self.agg_k > 0

    # source-side staging (called by DeviceMeshChannel)

    def slot_coords(self, slot: int) -> tuple[int, int]:
        """Dispatcher ring index -> (shard, per-shard slot) interleaving."""
        return slot % self.n_shards, (slot // self.n_shards) % self.n_slots_per_shard

    def _stage(self, word_frame: np.ndarray, slot: int) -> None:
        if self._staged is None:
            self._staged = np.zeros(
                (self.n_shards, self.n_slots_per_shard, self.slot_words),
                np.uint32)
        shard, idx = self.slot_coords(slot)
        self._staged[shard, idx] = word_frame
        self._staged_count += 1

    def _publish(self) -> None:
        """Deposit the staged generation over the ICI (collective_permute)."""
        if self._staged is None:
            return
        import jax.numpy as jnp

        self._mb = self._deposit(self._mb, jnp.asarray(self._staged),
                                 shift=self.shift)
        self._deposited += self._staged_count
        self._staged = None
        self._staged_count = 0

    # target side

    def slot_view(self, i: int):
        raise TransportError("device mailbox slots live in device memory; "
                             "use sweep()")

    def sweep(self, ctx, target_args, budget: int | None = None) -> list:
        """One compiled validate+execute pass over every deposited slot.
        ``budget`` is ignored: the sweep is a single device program, so a
        device lane may yield more than one message per dispatcher poll
        round (its yield still counts against the caller's total budget).
        READY results land in ``self.results`` and
        ``target_args['results']``."""
        from repro.core.api import Status
        from repro.kernels.ring_poll import BAD, INFLIGHT, READY

        if self._deposited == 0:
            self.last_coords = []
            return []
        if self.agg_k:
            return self._sweep_agg(target_args)
        status, out, cleared = self._sweep(self._mb, self.externals)
        status = np.asarray(status)
        out = np.asarray(out)
        self._mb = cleared
        statuses: list = []
        self.last_coords = []
        for shard in range(status.shape[0]):
            for slot in range(status.shape[1]):
                st = int(status[shard, slot])
                if st == READY:
                    self.results.append(out[shard, slot])
                    if isinstance(target_args, dict):
                        target_args.setdefault("results", []).append(
                            out[shard, slot])
                    statuses.append(Status.OK)
                    self.last_coords.append((shard, slot))
                elif st == BAD:
                    statuses.append(Status.REJECTED)
                    self.last_coords.append((shard, slot))
                elif st == INFLIGHT:
                    statuses.append(Status.IN_PROGRESS)
                    self.last_coords.append((shard, slot))
        consumed = sum(1 for s in statuses
                       if s in (Status.OK, Status.REJECTED))
        self.head += consumed
        self.consumed += consumed
        self._deposited = max(self._deposited - consumed, 0)
        return statuses

    def _sweep_agg(self, target_args) -> list:
        """Aggregate-container sweep: one batched kernel pass validates all
        containers + descriptors and ONE μVM launch executes every
        sub-record body; per-sub outcomes land in ``last_agg`` keyed by
        coordinates so the dispatcher completes them with host-lane
        semantics (per-sub NACK rebuild, poisoned sub = ERR with siblings
        unharmed, corrupt container = whole REJECT)."""
        from repro.core.api import AggSubResult, Status
        from repro.kernels.agg_poll import SUB_BAD, SUB_EMPTY, SUB_READY
        from repro.kernels.ring_poll import BAD, INFLIGHT, READY

        status, sub_st, out, cleared = self._sweep(self._mb, self.externals)
        status = np.asarray(status)
        sub_st = np.asarray(sub_st)
        out = np.asarray(out)
        self._mb = cleared
        statuses: list = []
        self.last_coords = []
        for shard in range(status.shape[0]):
            for slot in range(status.shape[1]):
                st = int(status[shard, slot])
                if st == READY:
                    subs: list[AggSubResult] = []
                    vals: list = []
                    for i in range(self.agg_k):
                        s_i = int(sub_st[shard, slot, i])
                        if s_i == SUB_EMPTY:
                            break
                        if s_i == SUB_READY:
                            subs.append(AggSubResult(
                                Status.OK, "", b"", 0,
                                value=out[shard, slot, i]))
                            vals.append(out[shard, slot, i])
                        elif s_i == SUB_BAD:
                            subs.append(AggSubResult(
                                Status.REJECTED, "", b"", 0,
                                error=TransportError(
                                    "poisoned sub-record (descriptor "
                                    "check mismatch)")))
                        else:                        # SUB_NACK
                            subs.append(AggSubResult(
                                Status.NACK_UNCACHED, "", b"", 0))
                    self.last_agg[(shard, slot)] = subs
                    while len(self.last_agg) > 2 * self.n_slots:
                        self.last_agg.pop(next(iter(self.last_agg)))
                    # ONE results entry per consumed container keeps the
                    # dispatcher's per-status result cursor aligned: a
                    # 1-sub container (transcoded singleton) yields its
                    # bare output, a K-sub one the per-sub list
                    entry = vals[0] if len(subs) == 1 and vals else vals
                    self.results.append(entry)
                    if isinstance(target_args, dict):
                        target_args.setdefault("results", []).extend(vals)
                    statuses.append(Status.OK)
                    self.last_coords.append((shard, slot))
                elif st == BAD:
                    statuses.append(Status.REJECTED)
                    self.last_coords.append((shard, slot))
                elif st == INFLIGHT:
                    statuses.append(Status.IN_PROGRESS)
                    self.last_coords.append((shard, slot))
        consumed = sum(1 for s in statuses
                       if s in (Status.OK, Status.REJECTED))
        self.head += consumed
        self.consumed += consumed
        self._deposited = max(self._deposited - consumed, 0)
        return statuses


class DeviceMeshChannel(Channel):
    def __init__(self, mailbox: DeviceMeshMailbox):
        super().__init__()
        self.mailbox = mailbox

    def put(self, data, slot: int, *, deliver_bytes: int | None = None) -> None:
        """Transcode a wire byte-frame into the device word-frame layout and
        stage it.  ``deliver_bytes`` short of the full frame stages the
        word-frame without its trailer word (the device-visible in-flight
        state); flush completes trailers before depositing.

        SLIM-aware: the μVM program is bound at mailbox-open time (the
        device-side link cache), so code words are *never* deposited over
        the ICI — a SLIM frame (code elided at the source) transcodes
        identically to a FULL one, and the payload is read through a
        zero-copy section view straight out of the sender's slab."""
        from repro.core.device_mailbox import pack_agg_word_frame, pack_word_frame

        mb = self.mailbox
        hdr = F.peek_header(data)
        if hdr is None:
            raise TransportError("device put of an empty frame")
        partial = deliver_bytes is not None and deliver_bytes < len(data)
        if hdr.is_agg:
            if not getattr(mb, "supports_agg", False):
                # without an agg_k bind the slot has no descriptor table or
                # per-sub body lanes: containers need an agg-bound mailbox
                raise TransportError(
                    "aggregate frame on a device mailbox opened without "
                    "agg_k= — bind an aggregate slot layout first")
            _, payload = F.frame_sections(data, hdr)
            try:
                batch = F.parse_agg(payload)
            except F.FrameError as e:
                raise TransportError(f"device agg transcode: {e}") from e
            pays: list[np.ndarray] = []
            hashes: list[int] = []
            for i in range(batch.n):
                if batch.kind(i) != F.CodeKind.UVM:
                    raise TransportError(
                        "device mesh accepts UVM sub-records only, got "
                        f"{batch.kind(i).name}")
                tiles = np.frombuffer(batch.payload(i), np.float32)
                if tiles.size != mb.body_words:
                    raise TransportError(
                        f"device agg sub payload {tiles.size} words != "
                        f"bound {mb.body_words}")
                pays.append(tiles)
                hashes.append(F.fletcher32(batch.name(i).encode())
                              & 0xFFFFFFFF)
            wf = pack_agg_word_frame(pays, hashes, mb.agg_k, mb.body_words,
                                     mb.slot_words, kind=int(hdr.code_kind),
                                     no_trailer=partial)
        else:
            if hdr.code_kind != F.CodeKind.UVM:
                raise TransportError(
                    f"device mesh accepts UVM frames only, got "
                    f"{hdr.code_kind.name}")
            _, payload = F.frame_sections(data, hdr)
            tiles = np.frombuffer(payload, np.float32)
            want = mb.body_words
            if tiles.size != want:
                raise TransportError(
                    f"device frame payload {tiles.size} words != bound "
                    f"{want} ({mb.n_tiles} x {mb.tile}x{mb.tile} tiles)")
            if getattr(mb, "supports_agg", False):
                # singleton on an agg-bound lane: a degenerate 1-sub
                # container.  The descriptor carries the *bound* hash — the
                # non-agg device path never name-checks (the program is
                # linked at open), and the per-sub NACK is an aggregate
                # concept (there is a handle to rebuild from); parity kept.
                wf = pack_agg_word_frame(
                    [tiles], [mb.bound_hash], mb.agg_k, mb.body_words,
                    mb.slot_words, kind=int(hdr.code_kind),
                    no_trailer=partial)
            else:
                name_hash = F.fletcher32(hdr.name.encode()) & 0xFFFFFFFF
                wf = pack_word_frame(tiles, mb.slot_words,
                                     kind=int(hdr.code_kind),
                                     name_hash=name_hash, no_trailer=partial)
        mb._stage(wf, slot)
        if partial:
            from repro.kernels.ring_poll import HDR_WORDS, TRAILER

            word_idx = (mb.slot_words - 1 if getattr(mb, "agg_k", 0)
                        else HDR_WORDS + mb.body_words)
            self._pending_trailers = getattr(self, "_pending_trailers", [])
            self._pending_trailers.append((slot, word_idx, TRAILER))
            self.stats["partial"] += 1
        self.stats["puts"] += 1
        self.stats["bytes"] += len(data)

    def flush(self) -> None:
        mb = self.mailbox
        for slot, word_idx, trailer in getattr(self, "_pending_trailers", []):
            shard, idx = mb.slot_coords(slot)
            if mb._staged is not None:
                mb._staged[shard, idx, word_idx] = trailer
        self._pending_trailers = []
        mb._publish()
        self.stats["flushes"] += 1


class DeviceMeshFabric(Fabric):
    """TPU-tier backend: open_mailbox binds a μVM program + external table
    (the device GOT) to a compiled deposit/sweep pair on a 1-D mesh axis."""

    kind = "device"

    def __init__(self, mesh, axis: str = "model", *, interpret: bool = True,
                 shift: int = 0):
        self.mesh, self.axis = mesh, axis
        self.interpret, self.shift = interpret, shift

    def open_mailbox(self, target_ctx, n_slots: int, slot_size: int,
                     *, prog=None, externals=None, n_tiles: int = 1,
                     tile: int = 128, agg_k: int = 0,
                     prog_name: str | None = None) -> DeviceMeshMailbox:
        """``target_ctx`` is unused (the mesh is the target); ``slot_size``
        must cover the bound word-frame.  ``prog``/``externals`` bind the
        μVM program — required (the device links at mailbox-open time).
        ``agg_k > 0`` binds the *aggregate container* slot layout (K
        sub-record bodies per slot, batched agg_poll sweep) and marks the
        lane coalesce-eligible; ``prog_name`` bounds sub-record name hashes
        (mismatches NACK per sub, None = wildcard)."""
        if prog is None:
            raise TransportError("DeviceMeshFabric.open_mailbox needs prog=")
        import jax.numpy as jnp

        n_shards = self.mesh.shape[self.axis]
        if externals is None:
            externals = jnp.zeros((n_shards, max(prog.n_ext, 1), tile, tile),
                                  jnp.float32)
        mb = DeviceMeshMailbox(self, self.mesh, self.axis, prog, externals,
                               n_slots, n_tiles, tile,
                               interpret=self.interpret, shift=self.shift,
                               agg_k=agg_k, prog_name=prog_name)
        if slot_size < mb.slot_size:
            raise TransportError(
                f"slot_size {slot_size} < device word-frame {mb.slot_size}B")
        return mb

    def connect(self, src_ctx, mailbox: DeviceMeshMailbox) -> DeviceMeshChannel:
        return DeviceMeshChannel(mailbox)


__all__ = ["DeviceMeshChannel", "DeviceMeshFabric", "DeviceMeshMailbox"]
