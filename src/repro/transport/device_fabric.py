"""Device-mesh fabric: the on-device mailbox/ppermute ifunc path behind the
same Fabric/Channel/Mailbox contract as the host backends.

The backend wraps ``core/device_mailbox.py``: a mailbox is a ring of
word-frames in (emulated) device memory per mesh shard; a put *transcodes*
the wire byte-frame (header + μVM code + f32 payload + trailer) into the
device word-frame layout — the NIC-offload moment — and stages it; flush
deposits every staged generation over the ICI via ``ppermute`` (the
RDMA-put analogue); the sweep validates all slots in one compiled
``ring_poll`` + ``ifunc_vm`` pass with the μVM program bound at
mailbox-open time (the device-side link cache).

Visibility is generation-batched: frames become consumable only after the
depositing flush, which is exactly the in-flight window the ProgressEngine
models on the host fabrics.  Deposits are slot-masked (only written slots
land), so flushing a new generation never clobbers deposited frames a
sweep has not consumed yet.

Kept in its own module so ``repro.transport`` imports without jax.
"""

from __future__ import annotations

import numpy as np

from repro.core import frame as F
from repro.transport.fabric import Channel, Fabric, Mailbox, TransportError


class DeviceMeshMailbox(Mailbox):
    """Ring of word-frame slots on every shard of a 1-D device mesh."""

    def __init__(self, fabric: "DeviceMeshFabric", mesh, axis: str, prog,
                 externals, n_slots: int, n_tiles: int, tile: int = 128,
                 *, interpret: bool = True, shift: int = 0):
        super().__init__()
        from repro.core.device_mailbox import empty_mailbox, make_deposit, make_sweep
        from repro.kernels.ring_poll import HDR_WORDS

        self.fabric = fabric
        self.mesh, self.axis, self.shift = mesh, axis, shift
        self.n_shards = mesh.shape[axis]
        self.n_slots_per_shard = n_slots
        self.n_slots = n_slots * self.n_shards       # dispatcher-visible ring
        self.n_tiles, self.tile = n_tiles, tile
        self.body_words = n_tiles * tile * tile
        self.slot_words = HDR_WORDS + self.body_words + 1
        self.slot_size = self.slot_words * 4         # byte-equivalent capacity
        self.prog = prog
        self.externals = externals                   # [n_shards, n_ext, T, T]
        self._mb = empty_mailbox(self.n_shards, n_slots, self.slot_words)
        self._deposit = make_deposit(mesh, axis)
        self._sweep = make_sweep(mesh, axis, prog, n_tiles, tile,
                                 interpret=interpret)
        self._staged: np.ndarray | None = None
        self._staged_count = 0
        self._deposited = 0                          # frames awaiting sweep
        self.results: list[np.ndarray] = []          # READY payload outputs
        self.last_coords: list[tuple[int, int]] = []  # (shard, slot) per
        #                                 status of the most recent sweep —
        #                                 the reply demux correlates device
        #                                 results to task corr-ids with this

    # source-side staging (called by DeviceMeshChannel)

    def slot_coords(self, slot: int) -> tuple[int, int]:
        """Dispatcher ring index -> (shard, per-shard slot) interleaving."""
        return slot % self.n_shards, (slot // self.n_shards) % self.n_slots_per_shard

    def _stage(self, word_frame: np.ndarray, slot: int) -> None:
        if self._staged is None:
            self._staged = np.zeros(
                (self.n_shards, self.n_slots_per_shard, self.slot_words),
                np.uint32)
        shard, idx = self.slot_coords(slot)
        self._staged[shard, idx] = word_frame
        self._staged_count += 1

    def _publish(self) -> None:
        """Deposit the staged generation over the ICI (collective_permute)."""
        if self._staged is None:
            return
        import jax.numpy as jnp

        self._mb = self._deposit(self._mb, jnp.asarray(self._staged),
                                 shift=self.shift)
        self._deposited += self._staged_count
        self._staged = None
        self._staged_count = 0

    # target side

    def slot_view(self, i: int):
        raise TransportError("device mailbox slots live in device memory; "
                             "use sweep()")

    def sweep(self, ctx, target_args, budget: int | None = None) -> list:
        """One compiled validate+execute pass over every deposited slot.
        ``budget`` is ignored: the sweep is a single device program, so a
        device lane may yield more than one message per dispatcher poll
        round (its yield still counts against the caller's total budget).
        READY results land in ``self.results`` and
        ``target_args['results']``."""
        from repro.core.api import Status
        from repro.kernels.ring_poll import BAD, INFLIGHT, READY

        if self._deposited == 0:
            self.last_coords = []
            return []
        status, out, cleared = self._sweep(self._mb, self.externals)
        status = np.asarray(status)
        out = np.asarray(out)
        self._mb = cleared
        statuses: list = []
        self.last_coords = []
        for shard in range(status.shape[0]):
            for slot in range(status.shape[1]):
                st = int(status[shard, slot])
                if st == READY:
                    self.results.append(out[shard, slot])
                    if isinstance(target_args, dict):
                        target_args.setdefault("results", []).append(
                            out[shard, slot])
                    statuses.append(Status.OK)
                    self.last_coords.append((shard, slot))
                elif st == BAD:
                    statuses.append(Status.REJECTED)
                    self.last_coords.append((shard, slot))
                elif st == INFLIGHT:
                    statuses.append(Status.IN_PROGRESS)
                    self.last_coords.append((shard, slot))
        consumed = sum(1 for s in statuses
                       if s in (Status.OK, Status.REJECTED))
        self.head += consumed
        self.consumed += consumed
        self._deposited = max(self._deposited - consumed, 0)
        return statuses


class DeviceMeshChannel(Channel):
    def __init__(self, mailbox: DeviceMeshMailbox):
        super().__init__()
        self.mailbox = mailbox

    def put(self, data, slot: int, *, deliver_bytes: int | None = None) -> None:
        """Transcode a wire byte-frame into the device word-frame layout and
        stage it.  ``deliver_bytes`` short of the full frame stages the
        word-frame without its trailer word (the device-visible in-flight
        state); flush completes trailers before depositing.

        SLIM-aware: the μVM program is bound at mailbox-open time (the
        device-side link cache), so code words are *never* deposited over
        the ICI — a SLIM frame (code elided at the source) transcodes
        identically to a FULL one, and the payload is read through a
        zero-copy section view straight out of the sender's slab."""
        from repro.core.device_mailbox import pack_word_frame

        mb = self.mailbox
        hdr = F.peek_header(data)
        if hdr is None:
            raise TransportError("device put of an empty frame")
        if hdr.is_agg:
            # the device tier already amortizes per-message cost its own
            # way: staged word-frames deposit as ONE slot-masked ppermute
            # generation and the sweep validates/executes the whole ring in
            # one compiled pass — an aggregate container has no word-frame
            # encoding (and nothing to gain) here, so coalescing stays
            # host-tier (the dispatcher never marks device lanes eligible)
            raise TransportError(
                "aggregate frames are host-tier only: the device mesh "
                "batches via generation deposits + whole-ring sweeps")
        if hdr.code_kind != F.CodeKind.UVM:
            raise TransportError(
                f"device mesh accepts UVM frames only, got {hdr.code_kind.name}")
        _, payload = F.frame_sections(data, hdr)
        tiles = np.frombuffer(payload, np.float32)
        want = mb.body_words
        if tiles.size != want:
            raise TransportError(
                f"device frame payload {tiles.size} words != bound {want} "
                f"({mb.n_tiles} x {mb.tile}x{mb.tile} tiles)")
        partial = deliver_bytes is not None and deliver_bytes < len(data)
        name_hash = F.fletcher32(hdr.name.encode()) & 0xFFFFFFFF
        wf = pack_word_frame(tiles, mb.slot_words, kind=int(hdr.code_kind),
                             name_hash=name_hash, no_trailer=partial)
        mb._stage(wf, slot)
        if partial:
            from repro.kernels.ring_poll import HDR_WORDS, TRAILER

            self._pending_trailers = getattr(self, "_pending_trailers", [])
            self._pending_trailers.append(
                (slot, HDR_WORDS + tiles.size, TRAILER))
            self.stats["partial"] += 1
        self.stats["puts"] += 1
        self.stats["bytes"] += len(data)

    def flush(self) -> None:
        mb = self.mailbox
        for slot, word_idx, trailer in getattr(self, "_pending_trailers", []):
            shard, idx = mb.slot_coords(slot)
            if mb._staged is not None:
                mb._staged[shard, idx, word_idx] = trailer
        self._pending_trailers = []
        mb._publish()
        self.stats["flushes"] += 1


class DeviceMeshFabric(Fabric):
    """TPU-tier backend: open_mailbox binds a μVM program + external table
    (the device GOT) to a compiled deposit/sweep pair on a 1-D mesh axis."""

    kind = "device"

    def __init__(self, mesh, axis: str = "model", *, interpret: bool = True,
                 shift: int = 0):
        self.mesh, self.axis = mesh, axis
        self.interpret, self.shift = interpret, shift

    def open_mailbox(self, target_ctx, n_slots: int, slot_size: int,
                     *, prog=None, externals=None, n_tiles: int = 1,
                     tile: int = 128) -> DeviceMeshMailbox:
        """``target_ctx`` is unused (the mesh is the target); ``slot_size``
        must cover the bound word-frame.  ``prog``/``externals`` bind the
        μVM program — required (the device links at mailbox-open time)."""
        if prog is None:
            raise TransportError("DeviceMeshFabric.open_mailbox needs prog=")
        import jax.numpy as jnp

        n_shards = self.mesh.shape[self.axis]
        if externals is None:
            externals = jnp.zeros((n_shards, max(prog.n_ext, 1), tile, tile),
                                  jnp.float32)
        mb = DeviceMeshMailbox(self, self.mesh, self.axis, prog, externals,
                               n_slots, n_tiles, tile,
                               interpret=self.interpret, shift=self.shift)
        if slot_size < mb.slot_size:
            raise TransportError(
                f"slot_size {slot_size} < device word-frame {mb.slot_size}B")
        return mb

    def connect(self, src_ctx, mailbox: DeviceMeshMailbox) -> DeviceMeshChannel:
        return DeviceMeshChannel(mailbox)


__all__ = ["DeviceMeshChannel", "DeviceMeshFabric", "DeviceMeshMailbox"]
