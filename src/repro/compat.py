"""Cross-version jax API normalizers shared by src/ and benchmarks/.

Mesh/shard_map shims live in ``repro.parallel.sharding`` (they need the
sharding imports); the jax-API helpers with no other home live here.
"""

from __future__ import annotations


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: newer
    releases return one dict, older ones a one-element list of dicts (one
    per device program), and either may be empty/None."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})
