"""Benchmark ifunc (paper §4.1): bumps a counter on the target."""

def counter_bump_payload_get_max_size(source_args, source_args_size):
    return source_args_size


def counter_bump_payload_init(payload, payload_size, source_args, source_args_size):
    payload[:source_args_size] = source_args[:source_args_size]
    return source_args_size


def counter_bump_main(payload, payload_size, target_args):
    target_args["count"] = target_args.get("count", 0) + 1
    target_args["last_bytes"] = payload_size
