"""Storage verb: aggregate u32 records — the ETL chain's terminal stage.

Receives the filtered records from the DPU hop and reduces them to
summary statistics; with an empty remaining chain, the flow layer packs
this result into the final OK reply to the origin — the only frame the
submitting host ever sees for the whole chain.

Payload: ``record u32 x n``  (raw bind: the upstream result as-is)
Result:  ``{"count": n, "sum": s, "min": lo, "max": hi}``
"""


def host_aggregate_main(payload, payload_size, target_args):
    n = payload_size // 4
    vals = struct.unpack_from("<%dI" % n, payload, 0)    # noqa: F821
    target_args["result"] = {
        "count": n,
        "sum": sum(vals),
        "min": min(vals) if vals else 0,
        "max": max(vals) if vals else 0,
    }


def host_aggregate_payload_get_max_size(source_args, source_args_size):
    return max(len(source_args), 1)


def host_aggregate_payload_init(payload, payload_size, source_args,
                                source_args_size):
    data = bytes(source_args)
    payload[:len(data)] = data
    return max(len(data), 1)
