"""Storage verb: aggregate u32 records — the ETL chain's terminal stage.

Receives the filtered records from the DPU hop and reduces them to
summary statistics; with an empty remaining chain, the flow layer packs
this result into the final OK reply to the origin — the only frame the
submitting host ever sees for the whole chain.

Streaming-aware (``IFUNC_STREAM``): on a FLAG_STREAM frame the main runs
once per arrived chunk (``target_args["stream"]`` carries the chunk
coordinates) and folds each chunk into a running accumulator — the
payload is reduced as it lands, never assembled.  Chunk boundaries are
arbitrary byte offsets, so a partial trailing record carries into the
next chunk.

Payload: ``record u32 x n``  (raw bind: the upstream result as-is)
Result:  ``{"count": n, "sum": s, "min": lo, "max": hi}``
"""

IFUNC_STREAM = True


def host_aggregate_main(payload, payload_size, target_args):
    st = target_args.get("stream") if isinstance(target_args, dict) else None
    if st is None:
        n = payload_size // 4
        vals = struct.unpack_from("<%dI" % n, payload, 0)    # noqa: F821
        target_args["result"] = {
            "count": n,
            "sum": sum(vals),
            "min": min(vals) if vals else 0,
            "max": max(vals) if vals else 0,
        }
        return
    state = target_args.setdefault("_agg_state", {})
    acc = state.get(st["key"])
    if acc is None:
        acc = state[st["key"]] = {"count": 0, "sum": 0, "min": None,
                                  "max": None, "tail": b""}
    data = acc["tail"] + bytes(payload[:payload_size])
    n = len(data) // 4
    vals = struct.unpack_from("<%dI" % n, data, 0)           # noqa: F821
    acc["tail"] = data[4 * n:]
    acc["count"] += n
    acc["sum"] += sum(vals)
    if vals:
        lo, hi = min(vals), max(vals)
        acc["min"] = lo if acc["min"] is None else min(acc["min"], lo)
        acc["max"] = hi if acc["max"] is None else max(acc["max"], hi)
    if st["last"]:
        state.pop(st["key"], None)
        target_args["result"] = {
            "count": acc["count"],
            "sum": acc["sum"],
            "min": acc["min"] if acc["min"] is not None else 0,
            "max": acc["max"] if acc["max"] is not None else 0,
        }


def host_aggregate_payload_get_max_size(source_args, source_args_size):
    return max(len(source_args), 1)


def host_aggregate_payload_init(payload, payload_size, source_args,
                                source_args_size):
    data = bytes(source_args)
    payload[:len(data)] = data
    return max(len(data), 1)
