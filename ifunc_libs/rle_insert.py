"""Paper §3.2 usage example analogue: the target 'database' doesn't know the
compression; the ifunc ships both the codec and the insert logic.
(run-length coding stands in for paq8px)."""


def _rle_encode(data):
    out = bytearray()
    i = 0
    while i < len(data):
        j = i
        while j < len(data) and j - i < 255 and data[j] == data[i]:
            j += 1
        out += bytes((j - i, data[i]))
        i = j
    return bytes(out)


def _rle_decode(data):
    out = bytearray()
    for k in range(0, len(data), 2):
        out += bytes([data[k + 1]]) * data[k]
    return bytes(out)


def rle_insert_payload_get_max_size(source_args, source_args_size):
    return 2 * source_args_size + 2  # worst case RLE


def rle_insert_payload_init(payload, payload_size, source_args, source_args_size):
    enc = _rle_encode(bytes(source_args))
    payload[:len(enc)] = enc
    return len(enc)


def rle_insert_main(payload, payload_size, target_args):
    record = _rle_decode(bytes(payload[:payload_size]))
    target_args["db"].append(record)
