"""Graph verb: fetch-data-to-host — the migrate-code-to-data inverse.

When the placement engine prices shipping the shard cheaper than queueing
behind a hot owner, the source injects this verb and the shard's packed
edge list comes back as the reply payload (RAW-tagged through the task
wire codec).  The source then runs the relax locally and registers a
local replica with the data directory, so later rounds can go LOCAL for
free.

Payload: ``sid(u32)``.  Reply: the shard's edge bytes.
"""


def graph_fetch_main(payload, payload_size, target_args):
    (sid,) = struct.unpack_from("<I", payload, 0)       # noqa: F821
    shards = target_args.get("shards") or {}
    if sid not in shards:
        raise ValueError("shard " + repr(sid) + " not resident here")
    target_args["result"] = bytes(shards[sid])


def graph_fetch_payload_get_max_size(source_args, source_args_size):
    return 4


def graph_fetch_payload_init(payload, payload_size, source_args,
                             source_args_size):
    import struct

    struct.pack_into("<I", payload, 0, int(source_args["sid"]))
    return 4
