"""Control-ring ifunc for the elastic fleet (see runtime/elastic.py).

Two payload modes share one library (one digest, one link):

* mode 0 — **beat**: a monotone sequence number plus the sender's worker
  id.  Executing it is the proof of liveness: the ElasticController
  sweeps each member's control mailbox, and only a live member's sweep
  advances ``target_args["hb"]`` — the controller then folds the beat
  into ``FleetState.heartbeat``.
* mode 1 — **manifest**: the source peer's view of the target's warm
  link-cache (name, digest) pairs, sent ONCE at re-admission so a
  restarted peer relinks from its local libraries instead of
  NACK-storming every SLIM frame.  Entries are handed to the
  ``target_args["relink"]`` callable the controller installs on the
  control ring (the restore must insert under the *manifest* digest —
  see ElasticController.readmit); without one they are stashed under
  ``target_args["hb"]["manifest"]``.

Wire layout (little-endian):

    mode 0:  u8 mode | u64 seq | u8 name_len | name bytes
    mode 1:  u8 mode | u16 count | count x (u8 name_len | name | 16B digest)
"""


def hb_beat_payload_get_max_size(source_args, source_args_size):
    if "manifest" in source_args:
        return 3 + sum(1 + len(n.encode()) + 16
                       for n, _ in source_args["manifest"])
    return 1 + 8 + 1 + len(source_args["worker"].encode())


def hb_beat_payload_init(payload, payload_size, source_args, source_args_size):
    if "manifest" in source_args:
        entries = source_args["manifest"]
        payload[0] = 1
        payload[1:3] = len(entries).to_bytes(2, "little")
        off = 3
        for name, digest in entries:
            nb = name.encode()
            payload[off] = len(nb)
            payload[off + 1:off + 1 + len(nb)] = nb
            off += 1 + len(nb)
            payload[off:off + 16] = digest
            off += 16
        return off
    nb = source_args["worker"].encode()
    payload[0] = 0
    payload[1:9] = int(source_args["seq"]).to_bytes(8, "little")
    payload[9] = len(nb)
    payload[10:10 + len(nb)] = nb
    return 10 + len(nb)


def hb_beat_main(payload, payload_size, target_args):
    mv = memoryview(payload)[:payload_size]
    if mv[0] == 0:
        nlen = mv[9]
        hb = target_args.get("hb")
        if hb is None:
            hb = target_args["hb"] = {}
        hb["seq"] = int.from_bytes(bytes(mv[1:9]), "little")
        hb["worker"] = bytes(mv[10:10 + nlen]).decode()
        hb["beats"] = hb.get("beats", 0) + 1
        return
    count = int.from_bytes(bytes(mv[1:3]), "little")
    off = 3
    entries = []
    for _ in range(count):
        nlen = mv[off]
        name = bytes(mv[off + 1:off + 1 + nlen]).decode()
        off += 1 + nlen
        digest = bytes(mv[off:off + 16])
        off += 16
        entries.append((name, digest))
    relink = target_args.get("relink")
    if relink is not None:
        for name, digest in entries:
            relink(name, digest)
    else:
        hb = target_args.get("hb")
        if hb is None:
            hb = target_args["hb"] = {}
        hb["manifest"] = entries
