"""Serving control verb: report a finished sequence back to the router.

Payload: ``rid(u32) | wlen(u8) worker_name | n(u32) | tokens(i32 x n)``.
Sent by a decode peer when a sequence's token budget is exhausted — the
*decode-side completion path*: a request is done when this frame lands
in the router's ``target_args["completions"]``, never at admission.
"""


def srv_complete_main(payload, payload_size, target_args):
    rid = struct.unpack_from("<I", payload, 0)[0]       # noqa: F821
    wlen = payload[4]
    off = 5
    worker = bytes(payload[off:off + wlen]).decode("ascii")
    off += wlen
    n = struct.unpack_from("<I", payload, off)[0]       # noqa: F821
    off += 4
    toks = list(struct.unpack_from(f"<{n}i", payload, off))  # noqa: F821
    comps = target_args.get("completions")
    if comps is None:
        comps = target_args["completions"] = []
    comps.append({"rid": rid, "worker": worker, "tokens": toks})
    target_args["result"] = {"rid": rid, "ok": True}


def srv_complete_payload_get_max_size(source_args, source_args_size):
    return 9 + len(source_args["worker"]) + 4 * len(source_args["tokens"])


def srv_complete_payload_init(payload, payload_size, source_args,
                              source_args_size):
    import struct

    import numpy as np

    struct.pack_into("<I", payload, 0, source_args["rid"])
    raw = source_args["worker"].encode("ascii")
    payload[4] = len(raw)
    off = 5
    payload[off:off + len(raw)] = raw
    off += len(raw)
    toks = np.ascontiguousarray(np.asarray(source_args["tokens"], np.int32))
    struct.pack_into("<I", payload, off, len(toks))
    off += 4
    traw = toks.tobytes()
    payload[off:off + len(traw)] = traw
    return off + len(traw)
