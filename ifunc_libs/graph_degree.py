"""Graph verb, device tier: frontier-expansion counts as a μVM matmul.

The device mesh holds the adjacency tiles (the graph's device-resident
shard: ``A[u, v] = w`` for edges u->v, bound per mesh shard as external
0 at mailbox-open time — the device GOT).  The payload tile broadcasts
the frontier indicator ``f`` across rows, so the MXU computes

    (F @ A)[i, v] = sum_u f[u] * A[u, v]   (every row identical)

— per vertex ``v``, the number of (weighted) frontier edges entering it:
the frontier-expansion / shard-hotness signal the placement engine routes
relax tasks with.  Pure matmul, so the TPU tier serves graph analytics
without any new kernel.
"""

import numpy as np

from repro.core.codegen import assemble

IFUNC_KIND = "uvm"

UVM_PROGRAM = assemble([
    ("loadp", 0),            # r0 <- frontier tile F (indicator in every row)
    ("loade", 1, 0),         # r1 <- external 0 ("A": this shard's adjacency)
    ("matmul", 2, 0, 1),     # MXU: expansion counts per column vertex
    ("store", 0, 2),
], symbols=("A",))


def graph_degree_main(payload, payload_size, target_args):
    """Host-side reference execution (device targets run the μVM)."""
    from repro.kernels import ops as K

    tiles = np.frombuffer(payload, np.float32).reshape(-1, 128, 128)
    ext = [np.asarray(target_args["externals"]["A"], np.float32)]
    out = K.uvm_execute(UVM_PROGRAM, tiles, ext)
    target_args["result"] = out
    return out


def graph_degree_payload_get_max_size(source_args, source_args_size):
    return np.asarray(source_args, np.float32).nbytes


def graph_degree_payload_init(payload, payload_size, source_args,
                              source_args_size):
    raw = np.ascontiguousarray(np.asarray(source_args, np.float32)).tobytes()
    payload[:len(raw)] = raw
    return len(raw)
