"""Storage verb: predicate-filter u32 records *on the DPU* — stage two of
the ETL chain in ``examples/storage_pipeline.py``.

Receives the decompressed records forwarded peer-to-peer by the CSD stage
(a ``kw`` bind: ``{"mode": "kw", "key": "data", "static": {"threshold":
T}}``), keeps the records ``>= threshold``, and hands the survivors to
the next hop.  Only the filtered subset continues down the chain — the
bandwidth asymmetry in-network filtering exists for.

Streaming-aware (``IFUNC_STREAM``): on a FLAG_STREAM frame the main runs
once per arrived chunk, reads the threshold from the stream's first four
bytes, and filters records as they land (partial trailing records carry
into the next chunk) — the survivors accumulate and publish as the
result on the final chunk.

Payload: ``threshold(u32) | record u32 x n``
Result:  the surviving records, one u32 each (``target_args["result"]``).
"""

IFUNC_STREAM = True


def dpu_filter_main(payload, payload_size, target_args):
    st = target_args.get("stream") if isinstance(target_args, dict) else None
    if st is None:
        (threshold,) = struct.unpack_from("<I", payload, 0)  # noqa: F821
        n = (payload_size - 4) // 4
        vals = struct.unpack_from("<%dI" % n, payload, 4)    # noqa: F821
        kept = [v for v in vals if v >= threshold]
        target_args["result"] = struct.pack(                 # noqa: F821
            "<%dI" % len(kept), *kept)
        return
    state = target_args.setdefault("_dpu_state", {})
    s = state.get(st["key"])
    if s is None:
        s = state[st["key"]] = {"buf": b"", "thr": None, "out": bytearray()}
    buf = s["buf"] + bytes(payload[:payload_size])
    off = 0
    if s["thr"] is None and len(buf) >= 4:
        (s["thr"],) = struct.unpack_from("<I", buf, 0)       # noqa: F821
        off = 4
    if s["thr"] is not None:
        n = (len(buf) - off) // 4
        vals = struct.unpack_from("<%dI" % n, buf, off)      # noqa: F821
        kept = [v for v in vals if v >= s["thr"]]
        s["out"] += struct.pack("<%dI" % len(kept), *kept)   # noqa: F821
        off += 4 * n
    s["buf"] = buf[off:]
    if st["last"]:
        state.pop(st["key"], None)
        target_args["result"] = bytes(s["out"])


def dpu_filter_payload_get_max_size(source_args, source_args_size):
    return 4 + len(source_args["data"])


def dpu_filter_payload_init(payload, payload_size, source_args,
                            source_args_size):
    import struct

    data = bytes(source_args["data"])
    struct.pack_into("<I", payload, 0, int(source_args["threshold"]))
    payload[4:4 + len(data)] = data
    return 4 + len(data)
