"""Storage verb: predicate-filter u32 records *on the DPU* — stage two of
the ETL chain in ``examples/storage_pipeline.py``.

Receives the decompressed records forwarded peer-to-peer by the CSD stage
(a ``kw`` bind: ``{"mode": "kw", "key": "data", "static": {"threshold":
T}}``), keeps the records ``>= threshold``, and hands the survivors to
the next hop.  Only the filtered subset continues down the chain — the
bandwidth asymmetry in-network filtering exists for.

Payload: ``threshold(u32) | record u32 x n``
Result:  the surviving records, one u32 each (``target_args["result"]``).
"""


def dpu_filter_main(payload, payload_size, target_args):
    (threshold,) = struct.unpack_from("<I", payload, 0)  # noqa: F821
    n = (payload_size - 4) // 4
    vals = struct.unpack_from("<%dI" % n, payload, 4)    # noqa: F821
    kept = [v for v in vals if v >= threshold]
    target_args["result"] = struct.pack(                 # noqa: F821
        "<%dI" % len(kept), *kept)


def dpu_filter_payload_get_max_size(source_args, source_args_size):
    return 4 + len(source_args["data"])


def dpu_filter_payload_init(payload, payload_size, source_args,
                            source_args_size):
    import struct

    data = bytes(source_args["data"])
    struct.pack_into("<I", payload, 0, int(source_args["threshold"]))
    payload[4:4 + len(data)] = data
    return 4 + len(data)
