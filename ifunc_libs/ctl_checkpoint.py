"""Control verb: trigger an async checkpoint on the worker."""

def ctl_checkpoint_payload_get_max_size(source_args, source_args_size):
    return 8


def ctl_checkpoint_payload_init(payload, payload_size, source_args, source_args_size):
    payload[:8] = int(source_args_size and int.from_bytes(source_args[:8], 'little')).to_bytes(8, 'little')
    return 8


def ctl_checkpoint_main(payload, payload_size, target_args):
    step = int.from_bytes(bytes(payload[:8]), 'little')
    ckpt = target_args.get("checkpoint")
    if ckpt is not None:
        ckpt(step)
    target_args["acks"].append(b"ckpt:%d" % step)
