"""Serving control verb: enqueue a generation request at the server.

Payload: ``rid(u32) | max_new(u32) | n_tokens(u32) | tokens(i32 x n)``.
The server's poll loop exposes ``target_args["queue"]``; requests appended
here are admitted into the continuous batcher.  Because the codec ships
with the frame, a frontend can evolve the request schema without
redeploying the server (the paper's §3.3 hot-upgrade property).

The main routine leans only on the target's *resident* symbols
(``struct`` — the libc of this world): it travels as code and relinks on
a target that never imported this module.
"""


def srv_enqueue_main(payload, payload_size, target_args):
    rid, max_new, n = struct.unpack_from("<III", payload, 0)  # noqa: F821
    toks = list(struct.unpack_from(f"<{n}i", payload, 12))    # noqa: F821
    q = target_args.get("queue")
    if q is None:
        q = target_args["queue"] = []
    q.append({"rid": rid, "max_new": max_new, "prompt": toks})
    # admission ack: travels back as the reply frame resolving the
    # frontend's submit() future (request/response serving)
    target_args["result"] = {"rid": rid, "queued": True, "depth": len(q)}


def srv_enqueue_payload_get_max_size(source_args, source_args_size):
    return 12 + 4 * len(source_args["prompt"])


def srv_enqueue_payload_init(payload, payload_size, source_args, source_args_size):
    import struct

    import numpy as np

    toks = np.ascontiguousarray(np.asarray(source_args["prompt"], np.int32))
    struct.pack_into("<III", payload, 0, source_args["rid"],
                     source_args["max_new"], len(toks))
    raw = toks.tobytes()
    payload[12:12 + len(raw)] = raw
    return 12 + len(raw)
