"""Serving data verb: land a migrating KV-cache slab in a decode peer's
cache slot, chunk by chunk, as the stream arrives.

``IFUNC_STREAM``: the transport calls the main once per arriving chunk
with ``target_args["stream"]`` describing the chunk's place in the
payload — each chunk is written straight into the reserved slot's
landing slab at its final offset.  No assembly buffer ever exists on the
decode peer; the slab IS the destination (sPIN-style execute-on-arrival,
PR 7).  The slab's first 12 bytes carry ``magic | rid | slot`` (kv.py),
so the first chunk routes the whole stream to its landing slab and later
chunks follow via per-stream rx state.

A plain (store-and-forward) frame is also accepted — the whole slab in
one call — but counted in ``target_args["counters"]["buffered_installs"]``:
the serving fabric asserts this stays ZERO, i.e. every migration
streamed.

target_args (shared ingress view, one per mailbox):
  slabs        {slot: bytearray}  preallocated landing slabs
  kv_arrivals  [slot, ...]        completed installs, consumed by pump()
  counters     {"buffered_installs": n}
Result (the stream's corr reply -> the prefill peer's install-ack
future): ``{rid, slot, streamed, bytes}``.
"""

IFUNC_STREAM = True


def kv_install_main(payload, payload_size, target_args):
    st = target_args.get("stream") if isinstance(target_args, dict) else None
    slabs = target_args["slabs"]
    if st is None:
        # store-and-forward fallback: whole slab in one frame.  Works, but
        # it means the payload was materialized twice — counted so the
        # fabric can assert the streamed path carried everything.
        rid, slot = struct.unpack_from("<II", payload, 4)  # noqa: F821
        slabs[slot][:payload_size] = payload[:payload_size]
        c = target_args.get("counters")
        if c is None:
            c = target_args["counters"] = {}
        c["buffered_installs"] = c.get("buffered_installs", 0) + 1
        target_args["kv_arrivals"].append(slot)
        target_args["result"] = {"rid": rid, "slot": slot,
                                 "streamed": False, "bytes": payload_size}
        return
    rx = target_args.get("_kv_rx")
    if rx is None:
        rx = target_args["_kv_rx"] = {}
    slot = rx.get(st["key"])
    if slot is None:
        # first chunk: the slab prefix names its landing slot
        slot = struct.unpack_from("<I", payload, 8)[0]   # noqa: F821
        rx[st["key"]] = slot
    off = st["offset"]
    slabs[slot][off:off + payload_size] = payload[:payload_size]
    if st["last"]:
        rx.pop(st["key"], None)
        rid = struct.unpack_from("<I", slabs[slot], 4)[0]  # noqa: F821
        target_args["kv_arrivals"].append(slot)
        target_args["result"] = {"rid": rid, "slot": slot,
                                 "streamed": True, "bytes": st["total_len"]}


def kv_install_payload_get_max_size(source_args, source_args_size):
    return len(source_args)


def kv_install_payload_init(payload, payload_size, source_args,
                            source_args_size):
    data = bytes(source_args)
    payload[:len(data)] = data
    return len(data)
