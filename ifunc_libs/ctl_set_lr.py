"""Control verb: hot-update the learning-rate scale without restart."""
import struct


def ctl_set_lr_payload_get_max_size(source_args, source_args_size):
    return 8


def ctl_set_lr_payload_init(payload, payload_size, source_args, source_args_size):
    payload[:8] = source_args[:8]
    return 8


def ctl_set_lr_main(payload, payload_size, target_args):
    (scale,) = struct.unpack("<d", bytes(payload[:8]))
    target_args["lr_scale"] = scale
    target_args["acks"].append(b"lr")
