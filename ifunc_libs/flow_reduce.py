"""Flow verb: integer-sum reduce at a gather rendezvous.

A gather ifunc has a two-sided contract:

* ``payload_init`` encodes ONE branch's contribution (here: the branch
  result as a signed 64-bit int) — this is what each branch frame
  carries to the gather peer;
* ``main`` runs ONCE, on the chunk-framed collection of all ``expect``
  contributions (``u32 k | (u32 len | contribution) x k`` — the
  ``tasks.wire.pack_chunks`` layout), after the rendezvous fills.

Result: the sum of the branch ints (``target_args["result"]``).
"""


def flow_reduce_main(payload, payload_size, target_args):
    (k,) = struct.unpack_from("<I", payload, 0)          # noqa: F821
    off = 4
    total = 0
    for _ in range(k):
        (ln,) = struct.unpack_from("<I", payload, off)   # noqa: F821
        off += 4
        if ln != 8:
            raise ValueError("flow_reduce chunk must be one <q int")
        (v,) = struct.unpack_from("<q", payload, off)    # noqa: F821
        off += ln
        total += v
    target_args["result"] = total


def flow_reduce_payload_get_max_size(source_args, source_args_size):
    return 8


def flow_reduce_payload_init(payload, payload_size, source_args,
                             source_args_size):
    import struct

    struct.pack_into("<q", payload, 0, int(source_args))
    return 8
