"""Flow verb: byte-reversing relay — the ``fig_flow`` benchmark's stage.

A deliberately cheap, verifiable transform (result = payload reversed) so
the benchmark measures the *plumbing* difference between an N-stage
continuation chain and N host-coordinated round-trips, not the stages'
compute.  Chaining it N times returns the original bytes for even N.

Payload: raw bytes.  Result: the bytes reversed (``target_args["result"]``).
"""


def flow_xform_main(payload, payload_size, target_args):
    target_args["result"] = bytes(payload[:payload_size])[::-1]


def flow_xform_payload_get_max_size(source_args, source_args_size):
    return max(len(source_args), 1)


def flow_xform_payload_init(payload, payload_size, source_args,
                            source_args_size):
    data = bytes(source_args)
    payload[:len(data)] = data
    return max(len(data), 1)
