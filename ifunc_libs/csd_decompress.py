"""Storage verb: RLE-decompress a record blob *at the CSD* — stage one of
the ETL chain in ``examples/storage_pipeline.py``.

The computational-storage move: the compressed blob never crosses to the
host — it is injected to (or already resident at) the bus-attached
target, decompresses there, and the flow layer forwards the expanded
records straight to the next hop (the DPU filter) via the frame's
continuation descriptor.

Streaming-aware (``IFUNC_STREAM``): on a FLAG_STREAM frame the main runs
once per arrived chunk and expands complete ``(value, count)`` runs as
they land — the 4-byte count header and any partial trailing run carry
into the next chunk, so arbitrary chunk boundaries are safe.  This is
also the decode half of the transport's ``rle`` wire codec (same run
format), so an rle-negotiated stream can feed this verb chunk-for-chunk.

Payload: ``nruns(u32) | (value u32, count u32) x nruns``  (RLE runs)
Result:  the expanded records, one u32 each (``target_args["result"]``).

Like every shipped verb, the main leans only on resident symbols
(``struct``) — it relinks on a target that never imported this module.
"""

IFUNC_STREAM = True


def csd_decompress_main(payload, payload_size, target_args):
    st = target_args.get("stream") if isinstance(target_args, dict) else None
    if st is None:
        (nruns,) = struct.unpack_from("<I", payload, 0)      # noqa: F821
        out = bytearray()
        off = 4
        for _ in range(nruns):
            v, c = struct.unpack_from("<II", payload, off)   # noqa: F821
            out += struct.pack("<I", v) * c                  # noqa: F821
            off += 8
        target_args["result"] = bytes(out)
        return
    state = target_args.setdefault("_csd_state", {})
    s = state.get(st["key"])
    if s is None:
        s = state[st["key"]] = {"buf": b"", "out": bytearray(), "hdr": False}
    buf = s["buf"] + bytes(payload[:payload_size])
    off = 0
    if not s["hdr"] and len(buf) >= 4:
        off = 4                      # the nruns header: the run walk below
        s["hdr"] = True              # consumes the actual run list
    while len(buf) - off >= 8:
        v, c = struct.unpack_from("<II", buf, off)           # noqa: F821
        s["out"] += struct.pack("<I", v) * c                 # noqa: F821
        off += 8
    s["buf"] = buf[off:]
    if st["last"]:
        state.pop(st["key"], None)
        target_args["result"] = bytes(s["out"])


def csd_decompress_payload_get_max_size(source_args, source_args_size):
    return max(len(source_args), 4)


def csd_decompress_payload_init(payload, payload_size, source_args,
                                source_args_size):
    n = len(source_args)
    payload[:n] = bytes(source_args)
    return max(n, 4)
