"""Storage verb: RLE-decompress a record blob *at the CSD* — stage one of
the ETL chain in ``examples/storage_pipeline.py``.

The computational-storage move: the compressed blob never crosses to the
host — it is injected to (or already resident at) the bus-attached
target, decompresses there, and the flow layer forwards the expanded
records straight to the next hop (the DPU filter) via the frame's
continuation descriptor.

Payload: ``nruns(u32) | (value u32, count u32) x nruns``  (RLE runs)
Result:  the expanded records, one u32 each (``target_args["result"]``).

Like every shipped verb, the main leans only on resident symbols
(``struct``) — it relinks on a target that never imported this module.
"""


def csd_decompress_main(payload, payload_size, target_args):
    (nruns,) = struct.unpack_from("<I", payload, 0)      # noqa: F821
    out = bytearray()
    off = 4
    for _ in range(nruns):
        v, c = struct.unpack_from("<II", payload, off)   # noqa: F821
        out += struct.pack("<I", v) * c                  # noqa: F821
        off += 8
    target_args["result"] = bytes(out)


def csd_decompress_payload_get_max_size(source_args, source_args_size):
    return max(len(source_args), 4)


def csd_decompress_payload_init(payload, payload_size, source_args,
                                source_args_size):
    n = len(source_args)
    payload[:n] = bytes(source_args)
    return max(n, 4)
