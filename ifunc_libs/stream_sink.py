"""Bench verb: minimal streaming consumer — counts the bytes of each
chunk as it lands (``IFUNC_STREAM``), publishing the running total as the
result on the final chunk.  Exists so the ``fig_stream`` benchmark
measures the *transport's* streamed delivery rate, not the cost of a real
reduction; it also accepts plain (non-stream) frames for the
store-and-forward comparison cells.

Payload: opaque bytes
Result:  total payload bytes observed (int)
"""

IFUNC_STREAM = True


def stream_sink_main(payload, payload_size, target_args):
    st = target_args.get("stream") if isinstance(target_args, dict) else None
    if st is None:
        target_args["result"] = payload_size
        return
    total = target_args.get("_sink", 0) + payload_size
    if st["last"]:
        target_args.pop("_sink", None)
        target_args["result"] = total
    else:
        target_args["_sink"] = total


def stream_sink_payload_get_max_size(source_args, source_args_size):
    return max(len(source_args), 1)


def stream_sink_payload_init(payload, payload_size, source_args,
                             source_args_size):
    data = bytes(source_args)
    payload[:len(data)] = data
    return max(len(data), 1)
