"""Result-returning test verb: sum the payload bytes and reply.

The minimal future-path ifunc: payload is raw bytes, the main puts the sum
in ``target_args["result"]`` (the reply convention) — unless the payload
starts with the poison marker 0xFF, in which case it raises, exercising
the exception-future path end to end.
"""

POISON = 0xFF


def task_sum_main(payload, payload_size, target_args):
    data = bytes(payload[:payload_size])
    if data and data[0] == 255:
        raise ValueError("poisoned payload")
    target_args["result"] = sum(data)


def task_sum_payload_get_max_size(source_args, source_args_size):
    return max(source_args_size, 1)


def task_sum_payload_init(payload, payload_size, source_args, source_args_size):
    payload[:source_args_size] = source_args[:source_args_size]
    return max(source_args_size, 1)
