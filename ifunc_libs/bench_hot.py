"""Cached-invocation benchmark ifunc (fig5_cached): a deliberately *heavy*
code section behind a trivial main.

``BLOB`` is inlined into the shipped code section as a module constant
(the serializer's ``.rodata``), so every FULL frame re-injects ~256 KiB of
code while a SLIM frame ships only the 84-byte header + payload.  That is
the paper's §3.4 scenario: big ifunc bodies whose injection cost must be
paid once, not per invocation.
"""

BLOB = b"\xa5\x5a\xc3\x3c" * (64 << 10)     # 256 KiB of .rodata


def bench_hot_payload_get_max_size(source_args, source_args_size):
    return source_args_size


def bench_hot_payload_init(payload, payload_size, source_args, source_args_size):
    payload[:source_args_size] = source_args[:source_args_size]
    return source_args_size


def bench_hot_main(payload, payload_size, target_args):
    target_args["count"] = target_args.get("count", 0) + 1
    target_args["code_bytes"] = len(BLOB)
