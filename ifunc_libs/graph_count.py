"""Graph verb: count edges above a weight threshold in one resident CSR
shard — the scatter/gather analytics query of
``examples/storage_pipeline.py``.

Scatter form: every shard owner gets this verb with a per-branch static
bind (``{"mode": "static", "static": {"sid": k, "wmin": w}}``); each
branch's count then rendezvouses at the gather peer, where
``flow_reduce`` sums the partials — partial aggregation at the gather
peer, not the host.

Payload: ``sid(u32) | wmin(f32)``
Result:  the edge count (int, ``target_args["result"]``).
"""


def graph_count_main(payload, payload_size, target_args):
    sid, wmin = struct.unpack_from("<If", payload, 0)    # noqa: F821
    shards = target_args.get("shards") or {}
    if sid not in shards:
        raise ValueError("shard " + repr(sid) + " not resident here")
    shard = shards[sid]
    base, nv = struct.unpack_from("<II", shard, 0)       # noqa: F821
    edges_off = 8 + 4 * (nv + 1)
    n_edges = (len(shard) - edges_off) // 8
    count = 0
    for k in range(n_edges):
        _, w = struct.unpack_from("<If", shard,          # noqa: F821
                                  edges_off + 8 * k)
        if w >= wmin:
            count += 1
    target_args["result"] = count


def graph_count_payload_get_max_size(source_args, source_args_size):
    return 8


def graph_count_payload_init(payload, payload_size, source_args,
                             source_args_size):
    import struct

    struct.pack_into("<If", payload, 0, int(source_args["sid"]),
                     float(source_args["wmin"]))
    return 8
