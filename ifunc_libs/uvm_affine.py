"""μVM ifunc: y = relu(x @ W) over 128x128 payload tiles.

Device-tier code kind (``IFUNC_KIND = "uvm"``): the frame carries the
assembled μVM program; a host target links it to the kernels-ops
interpreter, a device-mesh target runs it through the ``ifunc_vm`` Pallas
kernel with W bound from the target's external table (the device GOT).
"""

import numpy as np

from repro.core.codegen import assemble

IFUNC_KIND = "uvm"

UVM_PROGRAM = assemble([
    ("loadp", 0),            # r0 <- payload tile
    ("loade", 1, 0),         # r1 <- external 0 ("W", resident on target)
    ("matmul", 2, 0, 1),     # MXU
    ("relu", 2, 2),
    ("store", 0, 2),
], symbols=("W",))


def uvm_affine_main(payload, payload_size, target_args):
    """Host-side reference execution (targets normally link the shipped
    program instead of calling this)."""
    from repro.kernels import ops as K

    tiles = np.frombuffer(payload, np.float32).reshape(-1, 128, 128)
    ext = [np.asarray(target_args["externals"]["W"], np.float32)]
    out = K.uvm_execute(UVM_PROGRAM, tiles, ext)
    target_args["result"] = out
    return out


def uvm_affine_payload_get_max_size(source_args, source_args_size):
    return np.asarray(source_args, np.float32).nbytes


def uvm_affine_payload_init(payload, payload_size, source_args, source_args_size):
    raw = np.ascontiguousarray(np.asarray(source_args, np.float32)).tobytes()
    payload[:len(raw)] = raw
    return len(raw)
