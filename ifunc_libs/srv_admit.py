"""Serving control verb: reserve a decode slot for an incoming sequence.

Payload: ``rid(u32) | max_new(u32) | prompt_len(u32)``.  The decode
peer's poll loop exposes its :class:`~repro.serving.workers.DecodeWorker`
as ``target_args["worker"]``; the main asks it to reserve a batcher slot
(the landing address the KV stream will write into) and replies with the
slot plus the peer's *advertised wire codecs* in preference order — the
PR 9 negotiation path: the prefill tier arms its per-peer codec from
this ack instead of a constructor argument, so a decode peer can change
its accepted codecs without redeploying any sender.

``slot < 0`` in the ack means the decode tier refused (full, or the
prompt would not fit the cache window) — the router requeues.
"""


def srv_admit_main(payload, payload_size, target_args):
    rid, max_new, plen = struct.unpack_from("<III", payload, 0)  # noqa: F821
    worker = target_args["worker"]
    slot = worker.reserve(rid, plen, max_new)
    # admission ack -> the router's future: the slot is the stream's
    # landing address; the codec list is the negotiation advertisement
    target_args["result"] = {"rid": rid, "slot": slot,
                             "codecs": list(worker.codecs),
                             "queued": slot >= 0}


def srv_admit_payload_get_max_size(source_args, source_args_size):
    return 12


def srv_admit_payload_init(payload, payload_size, source_args,
                           source_args_size):
    import struct

    struct.pack_into("<III", payload, 0, source_args["rid"],
                     source_args["max_new"], source_args["prompt_len"])
    return 12
