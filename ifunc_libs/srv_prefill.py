"""Serving control verb: hand a prefill peer a routed generation job.

Payload::

    rid(u32) | slot(u32) | max_new(u32)
    | n_codecs(u8) | [len(u8) name ...]      decode peer's advertisement
    | dlen(u8) dpeer_name                    KV stream destination
    | n_tokens(u32) | tokens(i32 x n)        the prompt

The prefill peer's poll loop exposes ``target_args["jobs"]``; the main
appends the decoded job dict and acks with the queue depth.  The codec
advertisement rides along so the prefill worker can negotiate its wire
codec toward ``dpeer`` before the KV slab streams out.
"""


def srv_prefill_main(payload, payload_size, target_args):
    rid, slot, max_new = struct.unpack_from("<III", payload, 0)  # noqa: F821
    off = 12
    n_codecs = payload[off]
    off += 1
    codecs = []
    for _ in range(n_codecs):
        ln = payload[off]
        off += 1
        codecs.append(bytes(payload[off:off + ln]).decode("ascii"))
        off += ln
    dlen = payload[off]
    off += 1
    dpeer = bytes(payload[off:off + dlen]).decode("ascii")
    off += dlen
    n = struct.unpack_from("<I", payload, off)[0]               # noqa: F821
    off += 4
    prompt = list(struct.unpack_from(f"<{n}i", payload, off))   # noqa: F821
    jobs = target_args.get("jobs")
    if jobs is None:
        jobs = target_args["jobs"] = []
    jobs.append({"rid": rid, "slot": slot, "max_new": max_new,
                 "dpeer": dpeer, "codecs": codecs, "prompt": prompt})
    target_args["result"] = {"rid": rid, "accepted": True,
                             "depth": len(jobs)}


def srv_prefill_payload_get_max_size(source_args, source_args_size):
    base = 12 + 1 + sum(1 + len(c) for c in source_args["codecs"])
    base += 1 + len(source_args["dpeer"])
    return base + 4 + 4 * len(source_args["prompt"])


def srv_prefill_payload_init(payload, payload_size, source_args,
                             source_args_size):
    import struct

    import numpy as np

    struct.pack_into("<III", payload, 0, source_args["rid"],
                     source_args["slot"], source_args["max_new"])
    off = 12
    codecs = list(source_args["codecs"])
    payload[off] = len(codecs)
    off += 1
    for c in codecs:
        raw = c.encode("ascii")
        payload[off] = len(raw)
        off += 1
        payload[off:off + len(raw)] = raw
        off += len(raw)
    draw = source_args["dpeer"].encode("ascii")
    payload[off] = len(draw)
    off += 1
    payload[off:off + len(draw)] = draw
    off += len(draw)
    toks = np.ascontiguousarray(np.asarray(source_args["prompt"], np.int32))
    struct.pack_into("<I", payload, off, len(toks))
    off += 4
    raw = toks.tobytes()
    payload[off:off + len(raw)] = raw
    return off + len(raw)
