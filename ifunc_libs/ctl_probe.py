"""Control verb: liveness/telemetry probe. Appends an ack with the payload."""

def ctl_probe_payload_get_max_size(source_args, source_args_size):
    return max(source_args_size, 1)


def ctl_probe_payload_init(payload, payload_size, source_args, source_args_size):
    payload[:source_args_size] = source_args[:source_args_size]
    return max(source_args_size, 1)


def ctl_probe_main(payload, payload_size, target_args):
    target_args["acks"].append(bytes(payload[:payload_size]))
