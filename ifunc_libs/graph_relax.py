"""Graph verb: one delta-stepping-style relax round over one CSR shard.

The paper's semantic-graph-analysis scenario, migrate-code-to-data form:
the *edges stay put* (resident at the owning peer as
``target_args["shards"][sid]`` in the CSR layout of
``repro.tasks.graph`` — ``base | nv | offsets | (dst, w) runs``) and the
*frontier travels* — the payload carries the shard id plus the
(vertex, tentative-distance) pairs that changed last round.  Because the
shard is indexed by source vertex, the relax touches only the frontier's
edge runs; a *fetch* of the same shard moves every byte — the asymmetry
the placement engine's cost model prices.

Payload:  ``sid(u32) | nf(u32) | (vid u32, dist f32) x nf``
Reply:    ``nu(u32) | (vid u32, dist f32) x nu``   (via target_args["result"])

Like every shipped verb, the main leans only on resident symbols
(``struct``) — it relinks on a target that never imported this module.
An unknown shard id raises, which travels back as an exception future.
"""


def graph_relax_main(payload, payload_size, target_args):
    sid, nf = struct.unpack_from("<II", payload, 0)          # noqa: F821
    shards = target_args.get("shards") or {}
    if sid not in shards:
        raise ValueError("shard " + repr(sid) + " not resident here")
    shard = shards[sid]
    base, nv = struct.unpack_from("<II", shard, 0)           # noqa: F821
    edges_off = 8 + 4 * (nv + 1)
    best = {}
    for i in range(nf):
        v, d = struct.unpack_from("<If", payload, 8 + 8 * i)  # noqa: F821
        if not base <= v < base + nv:
            continue
        o0, o1 = struct.unpack_from("<II", shard, 8 + 4 * (v - base))  # noqa: F821
        for k in range(o0, o1):
            dst, w = struct.unpack_from("<If", shard, edges_off + 8 * k)  # noqa: F821
            cand = d + w
            if dst not in best or cand < best[dst]:
                best[dst] = cand
    out = bytearray(struct.pack("<I", len(best)))            # noqa: F821
    for v in sorted(best):
        out += struct.pack("<If", v, best[v])                # noqa: F821
    target_args["result"] = bytes(out)


def graph_relax_payload_get_max_size(source_args, source_args_size):
    return 8 + 8 * len(source_args["frontier"])


def graph_relax_payload_init(payload, payload_size, source_args,
                             source_args_size):
    import struct

    frontier = source_args["frontier"]
    struct.pack_into("<II", payload, 0, source_args["sid"], len(frontier))
    for i, (v, d) in enumerate(frontier):
        struct.pack_into("<If", payload, 8 + 8 * i, v, d)
    return 8 + 8 * len(frontier)
